//! Reduced dependence graph (RDG, §IV-A Fig. 3): a directed multigraph
//! whose nodes are variables/tensors and statements, and whose edges carry
//! the dependence vectors. Used to (a) order statements consistently with
//! intra-iteration dependencies for functional execution and (b) render the
//! analysis structure for documentation.

use std::collections::BTreeMap;

use super::ir::{Lhs, Operand, Pra, Statement};

/// One edge of the RDG: statement `to` reads `var` produced by statement
/// `from` (if any) with dependence vector `dep`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RdgEdge {
    pub var: String,
    /// Producing statement index (None for external tensor reads).
    pub from: Option<usize>,
    /// Consuming statement index.
    pub to: usize,
    /// Dependence vector (empty for tensor reads).
    pub dep: Vec<i64>,
}

/// The reduced dependence graph of a PRA.
#[derive(Debug, Clone)]
pub struct Rdg {
    pub edges: Vec<RdgEdge>,
    /// Producers: variable name → statement indices defining it.
    pub producers: BTreeMap<String, Vec<usize>>,
}

impl Rdg {
    /// Build the RDG of a PRA.
    pub fn build(pra: &Pra) -> Self {
        let mut producers: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (qi, s) in pra.statements.iter().enumerate() {
            producers.entry(s.lhs.name().to_string()).or_default().push(qi);
        }
        let mut edges = Vec::new();
        for (qi, s) in pra.statements.iter().enumerate() {
            for arg in &s.args {
                match arg {
                    Operand::Var { name, dep } => {
                        let from_list = producers.get(name.as_str());
                        match from_list {
                            Some(list) => {
                                for &from in list {
                                    edges.push(RdgEdge {
                                        var: name.clone(),
                                        from: Some(from),
                                        to: qi,
                                        dep: dep.clone(),
                                    });
                                }
                            }
                            None => edges.push(RdgEdge {
                                var: name.clone(),
                                from: None,
                                to: qi,
                                dep: dep.clone(),
                            }),
                        }
                    }
                    Operand::Tensor { name, .. } => edges.push(RdgEdge {
                        var: name.clone(),
                        from: None,
                        to: qi,
                        dep: vec![],
                    }),
                }
            }
        }
        Rdg { edges, producers }
    }

    /// Topological order of statements w.r.t. *intra-iteration* (zero
    /// dependence vector) edges. Needed so the functional simulator can
    /// execute the statements of one iteration in a single pass.
    ///
    /// Returns `None` if the zero-dependence subgraph has a cycle (an
    /// ill-formed PRA: an iteration would depend on itself).
    pub fn intra_iteration_order(&self, nstatements: usize) -> Option<Vec<usize>> {
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); nstatements];
        let mut indeg = vec![0usize; nstatements];
        for e in &self.edges {
            if let Some(from) = e.from {
                if e.dep.iter().all(|&d| d == 0) && from != e.to {
                    adj[from].push(e.to);
                    indeg[e.to] += 1;
                }
            }
        }
        // Kahn's algorithm, preferring original order for stability.
        let mut ready: Vec<usize> =
            (0..nstatements).filter(|&q| indeg[q] == 0).collect();
        ready.sort_unstable();
        let mut order = Vec::with_capacity(nstatements);
        while let Some(&q) = ready.first() {
            ready.remove(0);
            order.push(q);
            for &nxt in &adj[q] {
                indeg[nxt] -= 1;
                if indeg[nxt] == 0 {
                    let pos = ready.binary_search(&nxt).unwrap_or_else(|p| p);
                    ready.insert(pos, nxt);
                }
            }
        }
        if order.len() == nstatements {
            Some(order)
        } else {
            None
        }
    }

    /// Render a Graphviz DOT view of the RDG (documentation aid).
    pub fn to_dot(&self, statements: &[Statement]) -> String {
        let mut out = String::from("digraph rdg {\n  rankdir=LR;\n");
        for (qi, s) in statements.iter().enumerate() {
            let shape = if s.is_memory() { "box" } else { "ellipse" };
            out.push_str(&format!(
                "  S{qi} [label=\"{} ({})\", shape={shape}];\n",
                s.name, s.op
            ));
        }
        let mut ext = std::collections::BTreeSet::new();
        for e in &self.edges {
            match e.from {
                Some(from) => out.push_str(&format!(
                    "  S{from} -> S{} [label=\"{} d={:?}\"];\n",
                    e.to, e.var, e.dep
                )),
                None => {
                    ext.insert(e.var.clone());
                    out.push_str(&format!(
                        "  \"{}\" -> S{} [style=dashed];\n",
                        e.var, e.to
                    ));
                }
            }
        }
        for t in ext {
            out.push_str(&format!("  \"{t}\" [shape=cylinder];\n"));
        }
        // Output tensors
        for (qi, s) in statements.iter().enumerate() {
            if let Lhs::Tensor { name, .. } = &s.lhs {
                out.push_str(&format!(
                    "  \"{name}\" [shape=cylinder];\n  S{qi} -> \"{name}\" [style=dashed];\n"
                ));
            }
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::gesummv::gesummv;

    #[test]
    fn gesummv_rdg_structure() {
        let pra = gesummv();
        let rdg = Rdg::build(&pra);
        // 11 statements, every arg contributes >= 1 edge.
        assert!(rdg.edges.len() >= 11);
        // x is produced by S1 and S2.
        assert_eq!(rdg.producers["x"].len(), 2);
        // Y produced once.
        assert_eq!(rdg.producers["Y"].len(), 1);
    }

    #[test]
    fn gesummv_topological_order_valid() {
        let pra = gesummv();
        let rdg = Rdg::build(&pra);
        let order = rdg
            .intra_iteration_order(pra.statements.len())
            .expect("GESUMMV has no zero-dep cycle");
        assert_eq!(order.len(), 11);
        // Within an iteration, S3 (a = A*x) must come after S1/S2 (x=..).
        let pos = |name: &str| {
            let qi = pra
                .statements
                .iter()
                .position(|s| s.name == name)
                .unwrap();
            order.iter().position(|&q| q == qi).unwrap()
        };
        assert!(pos("S1") < pos("S3"));
        assert!(pos("S2") < pos("S3"));
        assert!(pos("S3") < pos("S6"));
        assert!(pos("S6") < pos("S11"));
        assert!(pos("S9") < pos("S11"));
    }

    #[test]
    fn cycle_detected() {
        use crate::pra::ir::*;
        use crate::polyhedral::ParamSpace;
        // a = copy(b); b = copy(a) with zero deps: cycle.
        let nd = 1;
        let pra = Pra {
            name: "cyc".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![
                Statement {
                    name: "S1".into(),
                    lhs: Lhs::Var("a".into()),
                    op: Op::Copy,
                    args: vec![Operand::var0("b", nd)],
                    cond: vec![],
                },
                Statement {
                    name: "S2".into(),
                    lhs: Lhs::Var("b".into()),
                    op: Op::Copy,
                    args: vec![Operand::var0("a", nd)],
                    cond: vec![],
                },
            ],
            tensors: vec![],
            requires: vec![],
        };
        let rdg = Rdg::build(&pra);
        assert!(rdg.intra_iteration_order(2).is_none());
    }

    #[test]
    fn dot_renders() {
        let pra = gesummv();
        let rdg = Rdg::build(&pra);
        let dot = rdg.to_dot(&pra.statements);
        assert!(dot.contains("digraph rdg"));
        assert!(dot.contains("\"A\""));
        assert!(dot.contains("\"Y\""));
    }
}
