//! Symbolic LSGP tiling (§III-C of the paper, Eq. 3–7).
//!
//! The iteration space is partitioned by `P = diag(p_0..p_{n-1})` into
//! `t_0×…×t_{n-1}` congruent tiles, one per processing element (dimensions
//! with `t_ℓ = 1` stay inside a single PE, e.g. the reduction dimension of
//! GEMM on a 2-D array). Every dependence-carrying transport statement is
//! split per Eq. 6 into one variant per solution `γ` of Eq. 7; variant
//! `γ = 0` keeps the dependence inside the tile (`d_J = d`), non-zero `γ`
//! crosses to a neighbour tile (`d_J = d + Pγ`, `d_K = −γ`).
//!
//! The module produces, for every (variant of every) statement, the tiled
//! polyhedral space whose lattice-point count is the statement's execution
//! volume (Eq. 12/13) — the input of the energy analysis.

pub mod gamma;
pub mod transform;

pub use gamma::gamma_candidates;
pub use transform::{
    pad_array, pad_bounds, tile_pra, ArrayMapping, TiledPra, TiledStmt,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::gesummv::gesummv;

    #[test]
    fn example2_gesummv_tiling_shape() {
        // Paper Example 2: 2×2 array. S7 (dep (0,1)) must split into the
        // two γ solutions {(0,0), (0,−1)}.
        let pra = gesummv();
        let tiled = tile_pra(&pra, &ArrayMapping::new(vec![2, 2]));
        let s7: Vec<&TiledStmt> = tiled
            .statements
            .iter()
            .filter(|s| s.base_name == "S7")
            .collect();
        assert_eq!(s7.len(), 2, "S7 splits into γ = (0,0) and (0,−1)");
        let gammas: Vec<Option<Vec<i64>>> =
            s7.iter().map(|s| s.gamma.clone()).collect();
        assert!(gammas.contains(&Some(vec![0, 0])));
        assert!(gammas.contains(&Some(vec![0, -1])));
        // d_K = −γ: the (0,−1) variant reads from tile k + (0,−1), i.e.
        // d_K = (0,1) as in the paper's d*6 = (0, 1−p1, 0, 1).
        let inter = s7.iter().find(|s| s.gamma == Some(vec![0, -1])).unwrap();
        assert_eq!(inter.dk, vec![0, 1]);
    }
}
