//! Enumeration of the tile-crossing vectors `γ` (Eq. 7 of the paper):
//! `{γ ∈ Z^n : −e < γ + P⁻¹d < e}`.
//!
//! For dependence components with `|d_ℓ| ≤ p_ℓ` (always the case here:
//! benchmark dependence vectors have unit components and the analysis
//! context requires `p_ℓ ≥ max |d_ℓ|`), the per-dimension solutions are
//!
//! * `d_ℓ = 0` → `γ_ℓ = 0`,
//! * `d_ℓ > 0` → `γ_ℓ ∈ {0, −1}`,
//! * `d_ℓ < 0` → `γ_ℓ ∈ {0, +1}`,
//!
//! and the candidate set is the cross product. Candidates whose tile-
//! membership constraint `j − d − Pγ ∈ J` is empty in a chamber simply
//! produce volume 0 there (e.g. `γ_ℓ = 0` with `d_ℓ = p_ℓ` — the
//! constraints self-police, no chamber analysis is needed up front).

/// Enumerate all `γ` candidates for a dependence vector `d`.
pub fn gamma_candidates(d: &[i64]) -> Vec<Vec<i64>> {
    let mut out: Vec<Vec<i64>> = vec![vec![]];
    for &dl in d {
        let choices: &[i64] = match dl.signum() {
            0 => &[0],
            1 => &[0, -1],
            _ => &[0, 1],
        };
        let mut next = Vec::with_capacity(out.len() * choices.len());
        for base in &out {
            for &c in choices {
                let mut g = base.clone();
                g.push(c);
                next.push(g);
            }
        }
        out = next;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_dep_single_gamma() {
        assert_eq!(gamma_candidates(&[0, 0]), vec![vec![0, 0]]);
    }

    #[test]
    fn example2_s7_gammas() {
        // Paper Example 2: d = (0, 1) → γ ∈ {(0,0), (0,−1)}.
        let g = gamma_candidates(&[0, 1]);
        assert_eq!(g.len(), 2);
        assert!(g.contains(&vec![0, 0]));
        assert!(g.contains(&vec![0, -1]));
    }

    #[test]
    fn negative_component() {
        // Jacobi-1D right-neighbour dep d = (1, −1).
        let g = gamma_candidates(&[1, -1]);
        assert_eq!(g.len(), 4);
        for gamma in [[0, 0], [-1, 0], [0, 1], [-1, 1]] {
            assert!(g.contains(&gamma.to_vec()), "{gamma:?}");
        }
    }

    #[test]
    fn three_dims() {
        let g = gamma_candidates(&[1, 0, 1]);
        assert_eq!(g.len(), 4);
    }
}
