//! The tiling transformation: PRA statements → tiled statement variants
//! with their polyhedral spaces (Eq. 5/6) and displacement vectors.

use crate::polyhedral::{
    AffineExpr, Constraint, Guard, SetConstraint, TiledSet,
};
use crate::pra::{Operand, Pra, Statement};

use super::gamma::gamma_candidates;

/// How the loop nest maps onto the processor array: number of tiles per
/// dimension (= array extent along that dimension; `1` keeps the whole
/// dimension inside one PE).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayMapping {
    pub t: Vec<i64>,
}

impl ArrayMapping {
    /// Create a mapping; every extent must be ≥ 1.
    pub fn new(t: Vec<i64>) -> Self {
        assert!(t.iter().all(|&x| x >= 1), "array extents must be >= 1");
        ArrayMapping { t }
    }

    /// Total number of PEs used.
    pub fn num_pes(&self) -> i64 {
        self.t.iter().product()
    }

    /// The exact-cover tile sizes for loop bounds `n`: `p_ℓ = ⌈N_ℓ/t_ℓ⌉`
    /// (the paper's sizing rule: as many tiles as PEs per dimension).
    pub fn tile_sizes(&self, n: &[i64]) -> Vec<i64> {
        n.iter().zip(&self.t).map(|(&nl, &tl)| (nl + tl - 1) / tl).collect()
    }

    /// Full concrete parameter vector `(N…, p…)` for loop bounds `n` under
    /// the exact-cover sizing rule.
    pub fn params_for(&self, n: &[i64]) -> Vec<i64> {
        let mut v = n.to_vec();
        v.extend(self.tile_sizes(n));
        v
    }
}

/// Pad an array shape to `ndims` entries with `t = 1` (unmapped deeper
/// loop dimensions stay inside a single PE) and truncate to `ndims` —
/// the one convention shared by `analyze_uniform`, the CLI and the
/// validator.
pub fn pad_array(array: &[i64], ndims: usize) -> Vec<i64> {
    let mut t = array.to_vec();
    while t.len() < ndims {
        t.push(1);
    }
    t.truncate(ndims);
    t
}

/// Pad loop bounds to `ndims` entries by replicating the last one and
/// truncate to `ndims` — [`pad_array`]'s twin for the bounds side,
/// shared by the CLI, the validator and the DSE explorer.
pub fn pad_bounds(bounds: &[i64], ndims: usize) -> Vec<i64> {
    let mut b = bounds.to_vec();
    let last = *b.last().expect("non-empty bounds");
    while b.len() < ndims {
        b.push(last);
    }
    b.truncate(ndims);
    b
}

/// One tiled statement variant.
#[derive(Debug, Clone)]
pub struct TiledStmt {
    /// Index of the originating statement in the PRA.
    pub stmt_index: usize,
    /// Name of the originating statement (e.g. `"S7"`).
    pub base_name: String,
    /// Display name including the variant (e.g. `"S7*2"`).
    pub name: String,
    /// `γ` of Eq. 7 for dependence-carrying transports, `None` for
    /// statements whose arguments all have zero dependence vectors.
    pub gamma: Option<Vec<i64>>,
    /// Original dependence vector `d` of the transported variable
    /// (all-zero when `gamma` is `None`).
    pub d: Vec<i64>,
    /// Inter-tile displacement `d_K = −γ` (zero when `gamma` is `None`).
    pub dk: Vec<i64>,
    /// Intra-tile displacement `d_J = d + Pγ` as parameter-affine
    /// expressions (used by the scheduler's causality constraints).
    pub dj: Vec<AffineExpr>,
    /// The tiled polyhedral space of Eq. 12/13 whose lattice-point count is
    /// this variant's execution volume.
    pub space: TiledSet,
}

impl TiledStmt {
    /// True when the variant crosses a tile boundary (`γ ≠ 0`).
    pub fn is_inter_tile(&self) -> bool {
        self.dk.iter().any(|&x| x != 0)
    }

    /// True when the dependence stays inside the tile but crosses
    /// iterations (`d ≠ 0, γ = 0`).
    pub fn is_intra_tile_dep(&self) -> bool {
        !self.is_inter_tile() && self.d.iter().any(|&x| x != 0)
    }
}

/// A tiled PRA: all statement variants plus the evaluation context.
#[derive(Debug, Clone)]
pub struct TiledPra {
    pub pra: Pra,
    pub mapping: ArrayMapping,
    pub statements: Vec<TiledStmt>,
    /// Chamber context every analysis result is valid under:
    /// `N_ℓ ≥ 1 ∧ p_ℓ ≥ max(1, max|d_ℓ|) ∧ p_ℓ ≤ N_ℓ`.
    pub context: Guard,
}

impl TiledPra {
    /// Extend the context with the exact-cover coupling
    /// `(t_ℓ−1)·p_ℓ < N_ℓ ≤ t_ℓ·p_ℓ` (the sizing rule of the paper's
    /// experiments). Returns a new context guard.
    pub fn exact_cover_context(&self) -> Guard {
        let sp = &self.pra.space;
        let np = sp.len();
        let mut g = self.context.clone();
        for l in 0..self.pra.ndims {
            let n = AffineExpr::param(np, sp.n_index(l));
            let p = AffineExpr::param(np, sp.p_index(l));
            let tl = self.mapping.t[l];
            // N_l <= t_l * p_l
            g = g.and(Constraint::ge(&p.clone().scaled(tl), &n));
            // N_l > (t_l - 1) * p_l
            g = g.and(Constraint::gt(&n, &p.clone().scaled(tl - 1)));
        }
        g
    }
}

/// Build the base tiled space (Eq. 3/4 + global membership) for a PRA.
fn base_space(pra: &Pra, mapping: &ArrayMapping) -> TiledSet {
    let sp = &pra.space;
    let np = sp.len();
    let n = pra.ndims;
    let p_idx: Vec<usize> = (0..n).map(|l| sp.p_index(l)).collect();
    let mut set = TiledSet::universe(n, np);
    for l in 0..n {
        set.add_tile_bounds(l, p_idx[l]);
        set.add_array_bounds(l, mapping.t[l]);
        // 0 ≤ i_l = j_l + p_l·k_l ≤ N_l − 1
        let mut a = vec![0i64; n];
        a[l] = 1;
        set.add_global_affine(&a, AffineExpr::zero(np), &p_idx);
        let mut an = vec![0i64; n];
        an[l] = -1;
        set.add_global_affine(
            &an,
            AffineExpr::param(np, sp.n_index(l)).plus(-1),
            &p_idx,
        );
    }
    set
}

/// Add a statement's condition space `I_q` to a tiled set.
fn add_conditions(set: &mut TiledSet, pra: &Pra, stmt: &Statement) {
    let sp = &pra.space;
    let p_idx: Vec<usize> =
        (0..pra.ndims).map(|l| sp.p_index(l)).collect();
    for c in &stmt.cond {
        set.add_global_affine(&c.a, c.konst.clone(), &p_idx);
    }
}

/// The dependence vector a statement transports, if any: the unique
/// non-zero `dep` among its arguments. Statements in this codebase carry at
/// most one (the PRA normal form of §IV-A splits compute from transport).
fn transported_dep(stmt: &Statement) -> Option<Vec<i64>> {
    let mut found: Option<Vec<i64>> = None;
    for a in &stmt.args {
        if let Operand::Var { dep, .. } = a {
            if dep.iter().any(|&x| x != 0) {
                assert!(
                    found.is_none(),
                    "statement {} transports more than one non-zero \
                     dependence; normalize the PRA first",
                    stmt.name
                );
                found = Some(dep.clone());
            }
        }
    }
    found
}

/// Tile a PRA onto a processor array (the §III-C transformation).
pub fn tile_pra(pra: &Pra, mapping: &ArrayMapping) -> TiledPra {
    assert_eq!(
        mapping.t.len(),
        pra.ndims,
        "mapping rank must equal loop depth"
    );
    let sp = &pra.space;
    let np = sp.len();
    let n = pra.ndims;
    let p_idx: Vec<usize> = (0..n).map(|l| sp.p_index(l)).collect();

    let mut statements = Vec::new();
    let mut dmax = vec![1i64; n];
    for (qi, stmt) in pra.statements.iter().enumerate() {
        let dep = transported_dep(stmt);
        match dep {
            None => {
                // Eq. 5: zero-dependence statement — volume from Eq. 12.
                let mut space = base_space(pra, mapping);
                add_conditions(&mut space, pra, stmt);
                statements.push(TiledStmt {
                    stmt_index: qi,
                    base_name: stmt.name.clone(),
                    name: stmt.name.clone(),
                    gamma: None,
                    d: vec![0; n],
                    dk: vec![0; n],
                    dj: vec![AffineExpr::zero(np); n],
                    space,
                });
            }
            Some(d) => {
                for (l, &dl) in d.iter().enumerate() {
                    dmax[l] = dmax[l].max(dl.abs());
                }
                // Eq. 6: one variant per γ of Eq. 7.
                for (vi, gamma) in gamma_candidates(&d).iter().enumerate() {
                    let mut space = base_space(pra, mapping);
                    add_conditions(&mut space, pra, stmt);
                    // d_J = d + P·γ (affine in p), membership j − d_J ∈ J.
                    let mut dj = Vec::with_capacity(n);
                    for l in 0..n {
                        let off = AffineExpr::param_scaled(
                            np,
                            p_idx[l],
                            gamma[l],
                            d[l],
                        );
                        dj.push(off.clone());
                        if d[l] != 0 || gamma[l] != 0 {
                            space.add_shifted_tile_membership(
                                l,
                                off,
                                p_idx[l],
                            );
                        }
                    }
                    // Source tile must exist: 0 ≤ k_ℓ + γ_ℓ ≤ t_ℓ − 1
                    // (implied by the condition space for well-formed PRAs,
                    // kept explicit for physical clarity).
                    for l in 0..n {
                        if gamma[l] != 0 {
                            let mut lo = SetConstraint::zero(2 * n, np);
                            lo.var_coeffs[space.kvar(l)] =
                                AffineExpr::constant(np, 1);
                            lo.konst = AffineExpr::constant(np, gamma[l]);
                            space.add(lo);
                            let mut hi = SetConstraint::zero(2 * n, np);
                            hi.var_coeffs[space.kvar(l)] =
                                AffineExpr::constant(np, -1);
                            hi.konst = AffineExpr::constant(
                                np,
                                mapping.t[l] - 1 - gamma[l],
                            );
                            space.add(hi);
                        }
                    }
                    let dk: Vec<i64> = gamma.iter().map(|&g| -g).collect();
                    let name = if gamma.iter().all(|&g| g == 0) {
                        format!("{}*{}", stmt.name, vi + 1)
                    } else {
                        format!("{}*{}", stmt.name, vi + 1)
                    };
                    statements.push(TiledStmt {
                        stmt_index: qi,
                        base_name: stmt.name.clone(),
                        name,
                        gamma: Some(gamma.clone()),
                        d: d.clone(),
                        dk,
                        dj,
                        space,
                    });
                }
            }
        }
    }

    // Context: N_ℓ ≥ 1, max(1, max|d_ℓ|) ≤ p_ℓ ≤ N_ℓ.
    let mut ctx = Vec::new();
    for l in 0..n {
        let nl = AffineExpr::param(np, sp.n_index(l));
        let pl = AffineExpr::param(np, sp.p_index(l));
        ctx.push(Constraint::ge(&nl, &AffineExpr::constant(np, 1)));
        ctx.push(Constraint::ge(&pl, &AffineExpr::constant(np, dmax[l])));
        ctx.push(Constraint::le(&pl, &nl));
    }
    TiledPra {
        pra: pra.clone(),
        mapping: mapping.clone(),
        statements,
        context: Guard::new(ctx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::{count_concrete, count_symbolic, SymbolicOptions};
    use crate::workloads::gesummv::gesummv;

    #[test]
    fn mapping_sizing_rule() {
        let m = ArrayMapping::new(vec![2, 2]);
        assert_eq!(m.num_pes(), 4);
        assert_eq!(m.tile_sizes(&[4, 5]), vec![2, 3]);
        assert_eq!(m.params_for(&[4, 5]), vec![4, 5, 2, 3]);
    }

    #[test]
    fn gesummv_variant_counts() {
        // 5 zero-dep statements (S1,S3,S4,S5,S8,S11 — S1 reads a tensor,
        // zero dep) and 3 transports (S2,S7,S10) with d=(1,0)/(0,1): two
        // variants each. S6, S9 have zero-dep args only.
        let pra = gesummv();
        let tiled = tile_pra(&pra, &ArrayMapping::new(vec![2, 2]));
        let zero_dep =
            tiled.statements.iter().filter(|s| s.gamma.is_none()).count();
        let variants =
            tiled.statements.iter().filter(|s| s.gamma.is_some()).count();
        assert_eq!(zero_dep, 8); // S1 S3 S4 S5 S6 S8 S9 S11
        assert_eq!(variants, 6); // S2, S7, S10 × 2 γ each
    }

    #[test]
    fn example9_volumes_through_tiling_path() {
        // The full pipeline must reproduce Example 9: Vol(S7*1)=12,
        // Vol(S7*2)=4 at N=(4,5), p=(2,3) on a 2×2 array.
        let pra = gesummv();
        let tiled = tile_pra(&pra, &ArrayMapping::new(vec![2, 2]));
        let params = [4i64, 5, 2, 3];
        let s7_intra = tiled
            .statements
            .iter()
            .find(|s| s.base_name == "S7" && !s.is_inter_tile())
            .unwrap();
        let s7_inter = tiled
            .statements
            .iter()
            .find(|s| s.base_name == "S7" && s.is_inter_tile())
            .unwrap();
        assert_eq!(count_concrete(&s7_intra.space, &[2, 2], &params), 12);
        assert_eq!(count_concrete(&s7_inter.space, &[2, 2], &params), 4);
        // And symbolically.
        let opts = SymbolicOptions::default();
        let sym1 =
            count_symbolic(&s7_intra.space, &[2, 2], &tiled.context, &opts);
        let sym2 =
            count_symbolic(&s7_inter.space, &[2, 2], &tiled.context, &opts);
        assert_eq!(sym1.eval(&params), 12);
        assert_eq!(sym2.eval(&params), 4);
    }

    #[test]
    fn total_compute_volume_is_iteration_space() {
        // Unconditioned compute statements (S3/S4) execute once per
        // iteration: volume = N0·N1 under exact cover.
        let pra = gesummv();
        let tiled = tile_pra(&pra, &ArrayMapping::new(vec![2, 2]));
        let s3 = tiled
            .statements
            .iter()
            .find(|s| s.base_name == "S3")
            .unwrap();
        assert_eq!(count_concrete(&s3.space, &[2, 2], &[4, 5, 2, 3]), 20);
    }

    #[test]
    fn intra_plus_inter_covers_dependence() {
        // For S2 (x-propagation, d=(1,0)): intra + inter variant volumes
        // must equal the number of iterations with i0 > 0 = (N0−1)·N1.
        let pra = gesummv();
        let tiled = tile_pra(&pra, &ArrayMapping::new(vec![2, 2]));
        for params in [[4i64, 5, 2, 3], [6, 6, 3, 3], [5, 7, 3, 4]] {
            let total: i128 = tiled
                .statements
                .iter()
                .filter(|s| s.base_name == "S2")
                .map(|s| count_concrete(&s.space, &[2, 2], &params))
                .sum();
            let expect = ((params[0] - 1) * params[1]) as i128;
            assert_eq!(total, expect, "params={params:?}");
        }
    }
}
