//! Register-allocation / data-movement *policies*: ablations of the
//! mapping decisions the energy model is sensitive to.
//!
//! The paper's §VI motivates using the symbolic analysis "for comparisons
//! with other loop nest accelerator architectures". The policy knob
//! reinterprets the access classification for architectures without the
//! TCPA's register classes:
//!
//! * [`Policy::Tcpa`] — the paper's model (FD for PE-local reuse, ID for
//!   neighbour data, one DRAM trip per tensor element).
//! * [`Policy::NoFeedback`] — PEs without feedback registers: intra-tile
//!   inter-iteration values spill to the I/O buffers and back (two IOb
//!   accesses replace one FD access). Models register-poor CGRA tiles.
//! * [`Policy::NoLocalReuse`] — no on-PE reuse at all: every transported
//!   value (intra- and inter-tile) round-trips the I/O buffer, the way a
//!   pure streaming architecture without a register hierarchy would
//!   execute the PRA. An Eyeriss-style "no local reuse" lower baseline.
//!
//! Only the *energy interpretation* changes; volumes are mapping
//! properties and stay identical — which is exactly why the symbolic
//! volumes can be reused across policies (one analysis, many
//! architectures).
//!
//! `Policy` is the **legacy closed enum**; the open-ended successor is
//! [`crate::energy::Backend`], which additionally bundles a per-target
//! [`EnergyTable`] and arbitrary routing. [`Policy::backend`] converts a
//! policy into the equivalent descriptor; new code (the `dse` sweep axis,
//! the CLI `--backend` flag) speaks backends directly.

use super::backend::Backend;
use super::classify::AccessClass;
use super::table::{EnergyTable, MemoryClass};

/// Architecture policy for interpreting access classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// The paper's TCPA register hierarchy.
    Tcpa,
    /// No feedback registers: FD accesses become IOb round trips.
    NoFeedback,
    /// No on-PE reuse: FD and neighbour-ID accesses become IOb round trips.
    NoLocalReuse,
}

impl Policy {
    /// All policies, for sweeps.
    pub const ALL: [Policy; 3] =
        [Policy::Tcpa, Policy::NoFeedback, Policy::NoLocalReuse];

    /// Short label.
    pub fn label(&self) -> &'static str {
        match self {
            Policy::Tcpa => "tcpa",
            Policy::NoFeedback => "no-fd",
            Policy::NoLocalReuse => "no-reuse",
        }
    }

    /// Memory classes one access of `class` touches under this policy.
    pub fn memory_classes(&self, class: AccessClass) -> Vec<MemoryClass> {
        // write-out + read-back + register
        let spill =
            || vec![MemoryClass::IOb, MemoryClass::IOb, MemoryClass::Rd];
        match (self, class) {
            (Policy::Tcpa, c) => c.memory_classes().to_vec(),
            (Policy::NoFeedback, AccessClass::Fd) => spill(),
            (Policy::NoFeedback, c) => c.memory_classes().to_vec(),
            (Policy::NoLocalReuse, AccessClass::Fd)
            | (Policy::NoLocalReuse, AccessClass::Id) => spill(),
            (Policy::NoLocalReuse, c) => c.memory_classes().to_vec(),
        }
    }

    /// Energy of one access of `class` under this policy.
    pub fn access_energy(&self, class: AccessClass, table: &EnergyTable) -> f64 {
        self.memory_classes(class)
            .iter()
            .map(|&c| table.access(c))
            .sum()
    }

    /// The equivalent [`Backend`] descriptor: this policy's routing,
    /// priced against `table`. `Policy::Tcpa` converts to the built-in
    /// [`Backend::tcpa`] (retabled), so legacy sweeps land in the same
    /// scenario group as the new default axis.
    pub fn backend(&self, table: &EnergyTable) -> Backend {
        let mut b = match self {
            // Keep the built-in name/description (retabled) so legacy
            // sweeps land in the same scenario group as the new axis.
            Policy::Tcpa => Backend::tcpa().with_table(table.clone()),
            Policy::NoFeedback => Backend::new(self.label(), table.clone())
                .with_description(
                    "legacy policy: FD accesses become IOb round trips",
                ),
            Policy::NoLocalReuse => Backend::new(self.label(), table.clone())
                .with_description(
                    "legacy policy: FD and neighbour-ID accesses become \
                     IOb round trips",
                ),
        };
        for class in AccessClass::ALL {
            b = b.with_route(class, &self.memory_classes(class));
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcpa_matches_default_classification() {
        let t = EnergyTable::table1_45nm();
        for c in [
            AccessClass::InputStream,
            AccessClass::OutputStream,
            AccessClass::Rd,
            AccessClass::Fd,
            AccessClass::Id,
        ] {
            assert_eq!(Policy::Tcpa.access_energy(c, &t), c.energy(&t));
        }
    }

    #[test]
    fn spill_policies_strictly_more_expensive_for_reuse() {
        let t = EnergyTable::table1_45nm();
        let fd_tcpa = Policy::Tcpa.access_energy(AccessClass::Fd, &t);
        let fd_nofd = Policy::NoFeedback.access_energy(AccessClass::Fd, &t);
        assert!(fd_nofd > fd_tcpa * 10.0, "{fd_nofd} vs {fd_tcpa}");
        let id_tcpa = Policy::Tcpa.access_energy(AccessClass::Id, &t);
        let id_noreuse =
            Policy::NoLocalReuse.access_energy(AccessClass::Id, &t);
        assert!(id_noreuse > id_tcpa);
        // DRAM-bound streams are policy-invariant.
        for p in Policy::ALL {
            assert_eq!(
                p.access_energy(AccessClass::InputStream, &t),
                AccessClass::InputStream.energy(&t)
            );
        }
    }

    #[test]
    fn backend_conversion_preserves_routing_and_energies() {
        for scale in [1.0, 0.3] {
            let t = EnergyTable::table1_45nm().scaled(scale, scale);
            for p in Policy::ALL {
                let b = p.backend(&t);
                for class in AccessClass::ALL {
                    assert_eq!(
                        b.route(class),
                        p.memory_classes(class).as_slice(),
                        "{} route for {class:?}",
                        p.label()
                    );
                    assert_eq!(
                        b.access_energy(class).to_bits(),
                        p.access_energy(class, &t).to_bits(),
                        "{} energy for {class:?}",
                        p.label()
                    );
                }
            }
        }
        let t45 = EnergyTable::table1_45nm();
        assert_eq!(Policy::Tcpa.backend(&t45).name(), "tcpa");
    }
}
