//! The energy model of §IV-A: Table I per-access/per-operation costs, the
//! access-location classifier `L(x)`, and per-statement energy profiles
//! (Eq. 9/10).

pub mod classify;
pub mod policy;
pub mod table;

pub use classify::{classify_displacement, AccessClass, AccessProfile};
pub use policy::Policy;
pub use table::{EnergyTable, MemoryClass};
