//! The energy model of §IV-A: Table I per-access/per-operation costs, the
//! access-location classifier `L(x)`, per-statement energy profiles
//! (Eq. 9/10), and pluggable cross-architecture [`Backend`] descriptors
//! (§VI comparisons).

pub mod backend;
pub mod classify;
pub mod policy;
pub mod table;

pub use backend::Backend;
pub use classify::{classify_displacement, AccessClass, AccessProfile};
pub use policy::Policy;
pub use table::{EnergyTable, MemoryClass};
