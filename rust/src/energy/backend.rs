//! Pluggable cross-architecture energy **backends** (the §VI use case:
//! "comparisons with other loop nest accelerator architectures").
//!
//! A [`Backend`] bundles everything the energy model needs to price a
//! mapped loop nest on one accelerator family:
//!
//! * a **name** (the CLI / report identity),
//! * an [`EnergyTable`] of per-access and per-operation costs, and
//! * a **routing table** `AccessClass → [MemoryClass]`: which memory
//!   structures one access of each class actually touches on that
//!   architecture.
//!
//! The symbolic volumes of a [`crate::analysis::SymbolicAnalysis`] are
//! *mapping* properties — they do not depend on the register hierarchy.
//! Only the interpretation of each access changes between architectures,
//! which is why one symbolic pass prices every backend (cf. the
//! CGRAs-vs-TCPAs comparison of Walter et al., arXiv:2502.12062, and the
//! table-driven per-target models of EnergyAnalyzer, arXiv:2305.14968).
//!
//! Built-in descriptors, all priced against Table I unless retabled with
//! [`Backend::with_table`]:
//!
//! * [`Backend::tcpa`] — the paper's TCPA register hierarchy, an exact
//!   Table-I reproduction (identity routing). Bit-for-bit equal to the
//!   pre-backend `energy_at` fast path.
//! * [`Backend::cgra`] — a CGRA tile cluster: there are no dedicated
//!   feedback registers or point-to-point neighbour links; every
//!   transported operand (PE-local inter-iteration *and* neighbour data)
//!   is driven through an output port onto the crossbar, staged in the
//!   shared register file, and read back through an input port
//!   (`FD/ID → OD + RD + ID`), per arXiv:2502.12062 §IV.
//! * [`Backend::gpu_sm`] — a GPU-streaming-multiprocessor-like target:
//!   no feedback registers; transported operands stage through the
//!   on-chip shared memory (our `IOb` class) with a write + read-back
//!   round trip into a general-purpose register
//!   (`FD/ID → IOb + IOb + RD`).
//! * [`Backend::systolic`] — a pure systolic array: ID-only neighbour
//!   transport. Values never sit in feedback registers; a PE-local
//!   inter-iteration value is pumped through the neighbour datapath each
//!   beat (`FD → OD + ID`); neighbour data lands in an input register
//!   exactly as on the TCPA.
//!
//! With Table-I energies the built-ins are pointwise ordered per access:
//! `tcpa ≤ systolic ≤ cgra ≤ gpu-sm` — so total energies inherit the
//! same order at every design point, which the DSE property tests pin.
//!
//! Custom architectures are plain values: start from [`Backend::new`]
//! (identity routing) and override routes/tables:
//!
//! ```
//! use tcpa_energy::energy::{AccessClass, Backend, EnergyTable, MemoryClass};
//! // A register-poor tile: local reuse spills to the I/O buffer.
//! let b = Backend::new("reg-poor", EnergyTable::table1_45nm())
//!     .with_route(
//!         AccessClass::Fd,
//!         &[MemoryClass::IOb, MemoryClass::IOb, MemoryClass::Rd],
//!     );
//! assert!(b.access_energy(AccessClass::Fd) > 32.0);
//! ```

use std::collections::BTreeMap;
use std::fmt;

use super::classify::{AccessClass, AccessProfile};
use super::table::{EnergyTable, MemoryClass};

/// One accelerator-architecture descriptor: name + energy table +
/// access-class routing. Identity (for scenario grouping, report columns
/// and `PartialEq`) is the full value — two backends differing only in
/// their table are distinct scenarios.
#[derive(Debug, Clone, PartialEq)]
pub struct Backend {
    name: String,
    /// Per-access / per-operation energies of this architecture.
    pub table: EnergyTable,
    /// One-line description (shown by the CLI `backends` listing).
    description: String,
    /// `routes[AccessClass::index()]` = memory classes one access of that
    /// class touches on this architecture.
    routes: [Vec<MemoryClass>; 5],
}

impl Backend {
    /// A backend with identity routing (the TCPA `L(x)` table) and the
    /// given energy table. Override routes with [`Backend::with_route`].
    pub fn new(name: impl Into<String>, table: EnergyTable) -> Self {
        let routes: [Vec<MemoryClass>; 5] = AccessClass::ALL
            .map(|c| c.memory_classes().to_vec());
        Backend {
            name: name.into(),
            table,
            description: String::new(),
            routes,
        }
    }

    /// The paper's TCPA register hierarchy — exact Table-I reproduction.
    pub fn tcpa() -> Self {
        Backend::new("tcpa", EnergyTable::table1_45nm()).with_description(
            "TCPA register hierarchy (paper Table I): FD for PE-local \
             reuse, ID for neighbour data",
        )
    }

    /// CGRA tile cluster (arXiv:2502.12062 §IV): all operand transport
    /// goes through the shared register file / crossbar instead of
    /// dedicated FD registers or point-to-point ID links.
    pub fn cgra() -> Self {
        let xbar: &[MemoryClass] =
            &[MemoryClass::Od, MemoryClass::Rd, MemoryClass::Id];
        Backend::new("cgra", EnergyTable::table1_45nm())
            .with_description(
                "CGRA: transported operands cross the shared register \
                 file / crossbar (OD+RD+ID) instead of FD/ID",
            )
            .with_route(AccessClass::Fd, xbar)
            .with_route(AccessClass::Id, xbar)
    }

    /// GPU-SM-like target: shared-memory staging, no feedback registers.
    pub fn gpu_sm() -> Self {
        let smem: &[MemoryClass] =
            &[MemoryClass::IOb, MemoryClass::IOb, MemoryClass::Rd];
        Backend::new("gpu-sm", EnergyTable::table1_45nm())
            .with_description(
                "GPU-SM-like: transported operands round-trip the shared \
                 memory (IOb+IOb+RD); no feedback registers",
            )
            .with_route(AccessClass::Fd, smem)
            .with_route(AccessClass::Id, smem)
    }

    /// Pure systolic array: ID-only neighbour transport; stationary
    /// values are pumped through the neighbour datapath each beat.
    pub fn systolic() -> Self {
        Backend::new("systolic", EnergyTable::table1_45nm())
            .with_description(
                "systolic: no feedback registers, PE-local reuse is \
                 pumped through the neighbour datapath (OD+ID)",
            )
            .with_route(
                AccessClass::Fd,
                &[MemoryClass::Od, MemoryClass::Id],
            )
    }

    /// All built-in backends, in CLI-listing order.
    pub fn builtins() -> Vec<Backend> {
        vec![
            Backend::tcpa(),
            Backend::cgra(),
            Backend::gpu_sm(),
            Backend::systolic(),
        ]
    }

    /// Look up a built-in backend by its name.
    pub fn by_name(name: &str) -> Option<Backend> {
        Backend::builtins().into_iter().find(|b| b.name == name)
    }

    /// The backend's identity / report label.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// One-line description for listings.
    pub fn description(&self) -> &str {
        &self.description
    }

    /// Replace the description.
    pub fn with_description(mut self, d: impl Into<String>) -> Self {
        self.description = d.into();
        self
    }

    /// Replace the energy table (e.g. a technology-scaled projection).
    pub fn with_table(mut self, table: EnergyTable) -> Self {
        self.table = table;
        self
    }

    /// Override the memory classes one access of `class` touches.
    pub fn with_route(
        mut self,
        class: AccessClass,
        route: &[MemoryClass],
    ) -> Self {
        self.routes[class.index()] = route.to_vec();
        self
    }

    /// Memory classes one access of `class` touches on this backend.
    pub fn route(&self, class: AccessClass) -> &[MemoryClass] {
        &self.routes[class.index()]
    }

    /// Energy of one access of `class`, in pJ, under this backend's
    /// routing and table.
    pub fn access_energy(&self, class: AccessClass) -> f64 {
        self.route(class).iter().map(|&c| self.table.access(c)).sum()
    }

    /// Per-execution memory-access counts of one statement profile, by
    /// class, routed through this backend. For [`Backend::tcpa`] this
    /// reproduces [`AccessProfile::mem_counts`] exactly (same
    /// construction). The per-query analysis path accumulates routes
    /// directly (`analysis::evaluate::counts_at_backend`) instead of
    /// materializing this map per statement; this helper is the
    /// one-statement reference view.
    pub fn route_counts(
        &self,
        profile: &AccessProfile,
    ) -> BTreeMap<MemoryClass, u32> {
        let mut counts: BTreeMap<MemoryClass, u32> = BTreeMap::new();
        for r in profile.reads.iter().chain(std::iter::once(&profile.write))
        {
            for &c in self.route(*r) {
                *counts.entry(c).or_insert(0) += 1;
            }
        }
        counts
    }

    /// Per-execution energy `E_q` of one statement profile (Eq. 9/10
    /// with this backend's routing and table), in pJ.
    pub fn stmt_energy(&self, profile: &AccessProfile) -> f64 {
        profile
            .reads
            .iter()
            .map(|&r| self.access_energy(r))
            .sum::<f64>()
            + self.table.op(profile.op)
            + self.access_energy(profile.write)
    }
}

impl fmt::Display for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcpa_routes_are_identity() {
        let b = Backend::tcpa();
        for c in AccessClass::ALL {
            assert_eq!(b.route(c), c.memory_classes());
            assert_eq!(
                b.access_energy(c),
                c.energy(&EnergyTable::table1_45nm())
            );
        }
    }

    #[test]
    fn builtin_access_energies_pointwise_ordered() {
        // tcpa ≤ systolic ≤ cgra ≤ gpu-sm per access class — the chain
        // that makes total energies comparable at every design point.
        let chain = [
            Backend::tcpa(),
            Backend::systolic(),
            Backend::cgra(),
            Backend::gpu_sm(),
        ];
        for w in chain.windows(2) {
            for c in AccessClass::ALL {
                assert!(
                    w[0].access_energy(c) <= w[1].access_energy(c),
                    "{} > {} on {c:?}",
                    w[0].name(),
                    w[1].name()
                );
            }
        }
        // Strict where the architectures actually differ.
        assert!(
            Backend::systolic().access_energy(AccessClass::Fd)
                > Backend::tcpa().access_energy(AccessClass::Fd)
        );
        assert!(
            Backend::gpu_sm().access_energy(AccessClass::Id)
                > Backend::cgra().access_energy(AccessClass::Id)
        );
    }

    #[test]
    fn builtin_names_unique_and_resolvable() {
        let all = Backend::builtins();
        assert_eq!(all.len(), 4);
        for b in &all {
            assert_eq!(
                Backend::by_name(b.name()).as_ref(),
                Some(b),
                "{} must round-trip through by_name",
                b.name()
            );
            assert!(!b.description().is_empty());
        }
        assert!(Backend::by_name("not-a-backend").is_none());
    }

    #[test]
    fn route_counts_identity_matches_profile_counts() {
        use crate::tiling::{tile_pra, ArrayMapping};
        use crate::workloads::gesummv::gesummv;
        let pra = gesummv();
        let tiled = tile_pra(&pra, &ArrayMapping::new(vec![2, 2]));
        let b = Backend::tcpa();
        for ts in &tiled.statements {
            let p = AccessProfile::of(&pra.statements[ts.stmt_index], ts);
            assert_eq!(b.route_counts(&p), p.mem_counts, "{}", ts.name);
        }
    }

    #[test]
    fn custom_route_and_table_compose() {
        let scaled = EnergyTable::table1_45nm().scaled(0.3, 0.12);
        let b = Backend::new("custom", EnergyTable::table1_45nm())
            .with_route(AccessClass::Fd, &[MemoryClass::Rd, MemoryClass::Rd])
            .with_table(scaled.clone());
        assert_eq!(
            b.route(AccessClass::Fd),
            &[MemoryClass::Rd, MemoryClass::Rd]
        );
        let expect = 2.0 * scaled.access(MemoryClass::Rd);
        assert!((b.access_energy(AccessClass::Fd) - expect).abs() < 1e-12);
        assert_eq!(b.to_string(), "custom");
    }
}
