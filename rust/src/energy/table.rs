//! Table I of the paper: per-access energies for the TCPA memory
//! hierarchy and per-operation energies, 45 nm technology (Pedram et al.,
//! "Dark Memory and Accelerator-Rich System Optimization in the Dark
//! Silicon Era", IEEE D&T 2017).

use std::fmt;

use crate::pra::Op;

/// The six memory classes of the processor-array memory system (§IV-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum MemoryClass {
    /// General-purpose register (intra-iteration dependencies).
    Rd,
    /// Feedback register (inter-iteration, PE-local reuse).
    Fd,
    /// Input register (data arriving from a neighbour PE or I/O buffer).
    Id,
    /// Output register (data leaving towards a neighbour PE or I/O buffer).
    Od,
    /// I/O buffer at the array periphery.
    IOb,
    /// Host DRAM (off-chip).
    Dram,
}

impl MemoryClass {
    /// All classes in Table-I order.
    pub const ALL: [MemoryClass; 6] = [
        MemoryClass::Rd,
        MemoryClass::Fd,
        MemoryClass::Id,
        MemoryClass::Od,
        MemoryClass::IOb,
        MemoryClass::Dram,
    ];

    /// Short label as used in the paper (RD/FD/ID/OD/IOb/DR).
    pub fn label(&self) -> &'static str {
        match self {
            MemoryClass::Rd => "RD",
            MemoryClass::Fd => "FD",
            MemoryClass::Id => "ID",
            MemoryClass::Od => "OD",
            MemoryClass::IOb => "IOb",
            MemoryClass::Dram => "DR",
        }
    }
}

impl fmt::Display for MemoryClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// Per-access and per-operation energies in pJ.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyTable {
    /// Indexed via [`MemoryClass`] discriminant order of [`MemoryClass::ALL`].
    pub access_pj: [f64; 6],
    /// Energy of one addition.
    pub add_pj: f64,
    /// Energy of one multiplication.
    pub mul_pj: f64,
}

impl EnergyTable {
    /// Table I values (45 nm).
    pub fn table1_45nm() -> Self {
        EnergyTable {
            access_pj: [
                0.12,   // RD  general-purpose register
                0.35,   // FD  feedback register
                0.24,   // ID  input register
                0.12,   // OD  output register
                16.0,   // IOb I/O buffer
                1280.0, // DR  DRAM
            ],
            add_pj: 0.36,
            mul_pj: 1.24,
        }
    }


    /// A uniformly scaled table for coarse technology projection (e.g.
    /// `table1_45nm().scaled(0.3, 0.12)` approximates a 7 nm node: on-chip
    /// access/logic energy shrinks faster than DRAM interface energy).
    /// `onchip` scales RD/FD/ID/OD/IOb and the operations; `dram` scales
    /// the DRAM access.
    pub fn scaled(&self, onchip: f64, dram: f64) -> Self {
        let mut t = self.clone();
        for (i, e) in t.access_pj.iter_mut().enumerate() {
            *e *= if MemoryClass::ALL[i] == MemoryClass::Dram {
                dram
            } else {
                onchip
            };
        }
        t.add_pj *= onchip;
        t.mul_pj *= onchip;
        t
    }

    /// Energy of one access to `class`, in pJ.
    pub fn access(&self, class: MemoryClass) -> f64 {
        let i = MemoryClass::ALL.iter().position(|&c| c == class).unwrap();
        self.access_pj[i]
    }

    /// Energy of computing `op` once, in pJ (`E(F_q)` of Eq. 9). Copy is a
    /// pure transport: zero compute energy. `Add3` activates the adder
    /// twice; `Sub`/`Max` cost one adder activation.
    pub fn op(&self, op: Op) -> f64 {
        match op {
            Op::Copy => 0.0,
            Op::Add | Op::Sub | Op::Max => self.add_pj,
            Op::Add3 => 2.0 * self.add_pj,
            Op::Mul => self.mul_pj,
        }
    }

    /// Number of adder / multiplier activations of `op` (for operation-
    /// count reporting next to the memory-access counts).
    pub fn op_activations(op: Op) -> (u32, u32) {
        match op {
            Op::Copy => (0, 0),
            Op::Add | Op::Sub | Op::Max => (1, 0),
            Op::Add3 => (2, 0),
            Op::Mul => (0, 1),
        }
    }

    /// Structural fingerprint over the exact bit patterns of every entry
    /// — the persistent analysis cache keys files by it, so a cache
    /// written under one table can never serve another.
    pub fn fingerprint(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        for e in self.access_pj {
            e.to_bits().hash(&mut h);
        }
        self.add_pj.to_bits().hash(&mut h);
        self.mul_pj.to_bits().hash(&mut h);
        h.finish()
    }

    /// Render Table I as markdown (for the `figures --table1` output).
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str("| Memory Class/Operation Type | Energy E [pJ] |\n");
        s.push_str("|---|---|\n");
        let names = [
            "General-purpose register (RD)",
            "Feedback register (FD)",
            "Input register (ID)",
            "Output register (OD)",
            "I/O buffer (IOb)",
            "DRAM (DR)",
        ];
        for (name, e) in names.iter().zip(self.access_pj) {
            s.push_str(&format!("| {name} | {e} |\n"));
        }
        s.push_str(&format!("| Addition (add) | {} |\n", self.add_pj));
        s.push_str(&format!("| Multiplication (mul) | {} |\n", self.mul_pj));
        s
    }
}

impl Default for EnergyTable {
    fn default() -> Self {
        EnergyTable::table1_45nm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        let t = EnergyTable::table1_45nm();
        assert_eq!(t.access(MemoryClass::Rd), 0.12);
        assert_eq!(t.access(MemoryClass::Fd), 0.35);
        assert_eq!(t.access(MemoryClass::Id), 0.24);
        assert_eq!(t.access(MemoryClass::Od), 0.12);
        assert_eq!(t.access(MemoryClass::IOb), 16.0);
        assert_eq!(t.access(MemoryClass::Dram), 1280.0);
        assert_eq!(t.op(Op::Add), 0.36);
        assert_eq!(t.op(Op::Mul), 1.24);
        assert_eq!(t.op(Op::Copy), 0.0);
        assert_eq!(t.op(Op::Add3), 0.72);
    }

    #[test]
    fn example9_statement_energies() {
        // E(S7*1) = E(FD) + E(RD) = 0.47 pJ; E(S7*2) = E(ID) + E(RD) = 0.36.
        let t = EnergyTable::table1_45nm();
        let e1 = t.access(MemoryClass::Fd) + t.access(MemoryClass::Rd);
        let e2 = t.access(MemoryClass::Id) + t.access(MemoryClass::Rd);
        assert!((e1 - 0.47).abs() < 1e-12);
        assert!((e2 - 0.36).abs() < 1e-12);
    }

    #[test]
    fn fingerprint_separates_tables() {
        let a = EnergyTable::table1_45nm();
        let b = a.scaled(0.3, 0.12);
        assert_eq!(a.fingerprint(), EnergyTable::default().fingerprint());
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn markdown_has_all_rows() {
        let md = EnergyTable::table1_45nm().to_markdown();
        assert_eq!(md.lines().count(), 10);
        assert!(md.contains("1280"));
    }
}
