//! The access-location classifier `L(x)` of §IV-A and the per-statement
//! energy/access profile (Eq. 9/10).

use std::collections::BTreeMap;

use crate::pra::{Lhs, Op, Operand, Statement};
use crate::tiling::TiledStmt;

use super::table::{EnergyTable, MemoryClass};

/// Where one read/write access lands (the five cases of the `L(x)` table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessClass {
    /// Input variable: DRAM → I/O buffer → input register.
    InputStream,
    /// Output variable: output register → I/O buffer → DRAM.
    OutputStream,
    /// Intra-iteration value in a general-purpose register.
    Rd,
    /// PE-local inter-iteration value in a feedback register.
    Fd,
    /// Value arriving from a neighbour PE in an input register.
    Id,
}

impl AccessClass {
    /// All access classes, in `L(x)`-table order. The index of a class in
    /// this array is the routing-table index used by
    /// [`crate::energy::Backend`].
    pub const ALL: [AccessClass; 5] = [
        AccessClass::InputStream,
        AccessClass::OutputStream,
        AccessClass::Rd,
        AccessClass::Fd,
        AccessClass::Id,
    ];

    /// Position of this class in [`AccessClass::ALL`].
    pub fn index(self) -> usize {
        AccessClass::ALL.iter().position(|&c| c == self).unwrap()
    }

    /// Short label (for CLI listings).
    pub fn label(&self) -> &'static str {
        match self {
            AccessClass::InputStream => "in-stream",
            AccessClass::OutputStream => "out-stream",
            AccessClass::Rd => "RD",
            AccessClass::Fd => "FD",
            AccessClass::Id => "ID",
        }
    }

    /// Memory classes touched by one access of this kind.
    pub fn memory_classes(&self) -> &'static [MemoryClass] {
        match self {
            AccessClass::InputStream => {
                &[MemoryClass::Dram, MemoryClass::IOb, MemoryClass::Id]
            }
            AccessClass::OutputStream => {
                &[MemoryClass::Dram, MemoryClass::IOb, MemoryClass::Od]
            }
            AccessClass::Rd => &[MemoryClass::Rd],
            AccessClass::Fd => &[MemoryClass::Fd],
            AccessClass::Id => &[MemoryClass::Id],
        }
    }

    /// Energy of one access, in pJ.
    pub fn energy(&self, table: &EnergyTable) -> f64 {
        self.memory_classes().iter().map(|&c| table.access(c)).sum()
    }
}

/// Classify the read of a transported variable by its displacement:
/// `RD` if `d = 0 ∧ γ = 0`, `FD` if `d ≠ 0 ∧ γ = 0`, `ID` if `γ ≠ 0`
/// (the last three cases of the `L(x)` table).
pub fn classify_displacement(d: &[i64], gamma: &[i64]) -> AccessClass {
    if gamma.iter().any(|&g| g != 0) {
        AccessClass::Id
    } else if d.iter().any(|&x| x != 0) {
        AccessClass::Fd
    } else {
        AccessClass::Rd
    }
}

/// Full access/energy profile of one tiled statement variant: everything
/// Eq. 9/10 needs, per execution.
#[derive(Debug, Clone)]
pub struct AccessProfile {
    /// Access class of each read (RHS operand, in order).
    pub reads: Vec<AccessClass>,
    /// Access class of the write (LHS).
    pub write: AccessClass,
    /// Operation computed (determines `E(F_q)`).
    pub op: Op,
    /// Memory accesses per execution, by class (reads + write combined).
    pub mem_counts: BTreeMap<MemoryClass, u32>,
    /// (adds, muls) per execution.
    pub op_counts: (u32, u32),
}

impl AccessProfile {
    /// Build the profile of a tiled statement variant (Eq. 9 for
    /// computational statements, Eq. 10 for transports — structurally the
    /// same sum: reads + op + write, with `E(copy) = 0`).
    pub fn of(stmt: &Statement, tiled: &TiledStmt) -> Self {
        let reads: Vec<AccessClass> = stmt
            .args
            .iter()
            .map(|arg| match arg {
                Operand::Tensor { .. } => AccessClass::InputStream,
                Operand::Var { dep, .. } => {
                    let gamma_zero = vec![0; dep.len()];
                    let gamma = tiled
                        .gamma
                        .as_deref()
                        .unwrap_or(&gamma_zero);
                    // Only the transported (non-zero-dep) operand carries
                    // the displacement; zero-dep reads are RD regardless.
                    if dep.iter().any(|&x| x != 0) {
                        classify_displacement(dep, gamma)
                    } else {
                        AccessClass::Rd
                    }
                }
            })
            .collect();
        let write = match &stmt.lhs {
            Lhs::Var(_) => AccessClass::Rd,
            Lhs::Tensor { .. } => AccessClass::OutputStream,
        };
        let mut mem_counts: BTreeMap<MemoryClass, u32> = BTreeMap::new();
        for r in reads.iter().chain(std::iter::once(&write)) {
            for &c in r.memory_classes() {
                *mem_counts.entry(c).or_insert(0) += 1;
            }
        }
        AccessProfile {
            reads,
            write,
            op: stmt.op,
            mem_counts,
            op_counts: EnergyTable::op_activations(stmt.op),
        }
    }

    /// Per-execution energy `E_q` in pJ (Eq. 9/10).
    pub fn energy(&self, table: &EnergyTable) -> f64 {
        self.reads.iter().map(|r| r.energy(table)).sum::<f64>()
            + table.op(self.op)
            + self.write.energy(table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::{tile_pra, ArrayMapping};
    use crate::workloads::gesummv::gesummv;

    fn profile_of(base: &str, inter: bool) -> AccessProfile {
        let pra = gesummv();
        let tiled = tile_pra(&pra, &ArrayMapping::new(vec![2, 2]));
        let ts = tiled
            .statements
            .iter()
            .find(|s| s.base_name == base && s.is_inter_tile() == inter)
            .unwrap();
        AccessProfile::of(&pra.statements[ts.stmt_index], ts)
    }

    #[test]
    fn displacement_classification() {
        assert_eq!(classify_displacement(&[0, 0], &[0, 0]), AccessClass::Rd);
        assert_eq!(classify_displacement(&[0, 1], &[0, 0]), AccessClass::Fd);
        assert_eq!(classify_displacement(&[0, 1], &[0, -1]), AccessClass::Id);
    }

    #[test]
    fn example9_s7_energies() {
        let t = EnergyTable::table1_45nm();
        // S7*1 (intra): FD read + RD write = 0.47 pJ.
        let p1 = profile_of("S7", false);
        assert_eq!(p1.reads, vec![AccessClass::Fd]);
        assert_eq!(p1.write, AccessClass::Rd);
        assert!((p1.energy(&t) - 0.47).abs() < 1e-12);
        // S7*2 (inter): ID read + RD write = 0.36 pJ.
        let p2 = profile_of("S7", true);
        assert_eq!(p2.reads, vec![AccessClass::Id]);
        assert!((p2.energy(&t) - 0.36).abs() < 1e-12);
    }

    #[test]
    fn input_and_output_streams() {
        let t = EnergyTable::table1_45nm();
        // S1: x = X[i1] — input stream read + RD write.
        let p = profile_of("S1", false);
        assert_eq!(p.reads, vec![AccessClass::InputStream]);
        assert_eq!(p.write, AccessClass::Rd);
        assert!((p.energy(&t) - (1280.0 + 16.0 + 0.24 + 0.12)).abs() < 1e-9);
        // S11: Y[i0] = sA + sB — two RD reads, add, output stream write.
        let p11 = profile_of("S11", false);
        assert_eq!(p11.reads, vec![AccessClass::Rd, AccessClass::Rd]);
        assert_eq!(p11.write, AccessClass::OutputStream);
        let expect = 2.0 * 0.12 + 0.36 + (1280.0 + 16.0 + 0.12);
        assert!((p11.energy(&t) - expect).abs() < 1e-9);
        assert_eq!(p11.op_counts, (1, 0));
    }

    #[test]
    fn mem_counts_aggregate() {
        let p = profile_of("S11", false);
        assert_eq!(p.mem_counts[&MemoryClass::Rd], 2);
        assert_eq!(p.mem_counts[&MemoryClass::Dram], 1);
        assert_eq!(p.mem_counts[&MemoryClass::IOb], 1);
        assert_eq!(p.mem_counts[&MemoryClass::Od], 1);
    }
}
