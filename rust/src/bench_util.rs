//! Minimal benchmarking harness (the offline vendor tree has no
//! criterion): warmup + N timed repetitions, reporting min/median/mean.
//! All `cargo bench` targets are `harness = false` binaries built on this.

use std::time::{Duration, Instant};

/// Statistics of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
    pub reps: usize,
}

impl BenchStats {
    /// Render a compact one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "min {:?}  median {:?}  mean {:?}  max {:?}  ({} reps)",
            self.min, self.median, self.mean, self.max, self.reps
        )
    }
}

/// Time `f` with `warmup` unmeasured and `reps` measured runs.
pub fn bench<T>(
    warmup: usize,
    reps: usize,
    mut f: impl FnMut() -> T,
) -> BenchStats {
    assert!(reps >= 1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let total: Duration = times.iter().sum();
    BenchStats {
        min: times[0],
        median: times[times.len() / 2],
        mean: total / reps as u32,
        max: *times.last().unwrap(),
        reps,
    }
}

/// Time `f` once (for the long simulation points of Fig. 4 where
/// repetitions are impractical — the paper's simulator points are also
/// single runs).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (Duration, T) {
    let t0 = Instant::now();
    let v = f();
    (t0.elapsed(), v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_orders_stats() {
        let s = bench(1, 5, || std::hint::black_box((0..100).sum::<u64>()));
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(s.reps, 5);
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn time_once_returns_value() {
        let (d, v) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
