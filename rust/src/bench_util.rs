//! Minimal benchmarking harness (the offline vendor tree has no
//! criterion): warmup + N timed repetitions, reporting min/median/mean.
//! All `cargo bench` targets are `harness = false` binaries built on this.
//!
//! Also hosts the machine-readable results channel: benches append their
//! numbers as one top-level section of a JSON results file (see
//! [`write_bench_section`]), so CI tracks the perf trajectory across PRs.
//! Symbolic-analysis benches write to `BENCH_symbolic.json`
//! ([`bench_symbolic_json_path`]); simulation benches write to
//! `BENCH_sim.json` ([`bench_sim_json_path`]).

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Statistics of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
    pub reps: usize,
}

impl BenchStats {
    /// Render a compact one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "min {:?}  median {:?}  mean {:?}  max {:?}  ({} reps)",
            self.min, self.median, self.mean, self.max, self.reps
        )
    }
}

/// Time `f` with `warmup` unmeasured and `reps` measured runs.
pub fn bench<T>(
    warmup: usize,
    reps: usize,
    mut f: impl FnMut() -> T,
) -> BenchStats {
    assert!(reps >= 1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let total: Duration = times.iter().sum();
    BenchStats {
        min: times[0],
        median: times[times.len() / 2],
        mean: total / reps as u32,
        max: *times.last().unwrap(),
        reps,
    }
}

/// Time `f` once (for the long simulation points of Fig. 4 where
/// repetitions are impractical — the paper's simulator points are also
/// single runs).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (Duration, T) {
    let t0 = Instant::now();
    let v = f();
    (t0.elapsed(), v)
}

/// Where the symbolic benches record machine-readable results:
/// `$BENCH_SYMBOLIC_JSON` if set, else `BENCH_symbolic.json` in the
/// current directory (the package root under `cargo bench`).
pub fn bench_symbolic_json_path() -> PathBuf {
    std::env::var_os("BENCH_SYMBOLIC_JSON")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("BENCH_symbolic.json"))
}

/// Where the simulation benches record machine-readable results:
/// `$BENCH_SIM_JSON` if set, else `BENCH_sim.json` in the current
/// directory. Kept separate from [`bench_symbolic_json_path`] so the
/// simulator perf trajectory (tick vs event engine) is its own CI
/// artifact.
pub fn bench_sim_json_path() -> PathBuf {
    std::env::var_os("BENCH_SIM_JSON")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("BENCH_sim.json"))
}

/// Read-modify-write one top-level section of a JSON object file: the
/// file holds `{"section": value, ...}`; `body` (itself a JSON value)
/// replaces or appends the named section, preserving the others. An
/// unreadable or malformed file is treated as empty, so a broken run can
/// never wedge the results channel.
pub fn write_bench_section(
    path: &Path,
    section: &str,
    body: &str,
) -> std::io::Result<()> {
    let mut sections: Vec<(String, String)> =
        match std::fs::read_to_string(path) {
            Ok(s) => parse_sections(&s).unwrap_or_default(),
            Err(_) => Vec::new(),
        };
    match sections.iter_mut().find(|(k, _)| k == section) {
        Some((_, v)) => *v = body.to_string(),
        None => sections.push((section.to_string(), body.to_string())),
    }
    let mut out = String::from("{\n");
    for (i, (k, v)) in sections.iter().enumerate() {
        out.push_str(&format!(
            "  {k:?}: {v}{}\n",
            if i + 1 < sections.len() { "," } else { "" }
        ));
    }
    out.push_str("}\n");
    std::fs::write(path, out)
}

/// Minimal tolerant scanner for `{"key": value, ...}` with nested
/// objects/arrays/strings; returns `None` on anything unexpected.
fn parse_sections(s: &str) -> Option<Vec<(String, String)>> {
    let inner = s.trim().strip_prefix('{')?.strip_suffix('}')?;
    let b = inner.as_bytes();
    let mut i = 0usize;
    let mut out = Vec::new();
    loop {
        while i < b.len() && (b[i].is_ascii_whitespace() || b[i] == b',') {
            i += 1;
        }
        if i >= b.len() {
            break;
        }
        if b[i] != b'"' {
            return None;
        }
        i += 1;
        let k0 = i;
        while i < b.len() && b[i] != b'"' {
            if b[i] == b'\\' {
                i += 1;
            }
            i += 1;
        }
        if i >= b.len() {
            return None;
        }
        let key = String::from_utf8_lossy(&b[k0..i]).into_owned();
        i += 1;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        if i >= b.len() || b[i] != b':' {
            return None;
        }
        i += 1;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        let v0 = i;
        let mut depth = 0i32;
        let mut in_str = false;
        while i < b.len() {
            let c = b[i];
            if in_str {
                if c == b'\\' {
                    i += 1;
                } else if c == b'"' {
                    in_str = false;
                }
            } else {
                match c {
                    b'"' => in_str = true,
                    b'{' | b'[' => depth += 1,
                    b'}' | b']' => depth -= 1,
                    b',' if depth == 0 => break,
                    _ => {}
                }
            }
            i += 1;
        }
        if depth != 0 || in_str {
            return None;
        }
        out.push((
            key,
            String::from_utf8_lossy(&b[v0..i]).trim().to_string(),
        ));
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_orders_stats() {
        let s = bench(1, 5, || std::hint::black_box((0..100).sum::<u64>()));
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(s.reps, 5);
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn time_once_returns_value() {
        let (d, v) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn bench_sections_merge_and_overwrite() {
        let path = std::env::temp_dir().join(format!(
            "tcpa-bench-json-{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        write_bench_section(&path, "a", r#"{"x": 1, "s": "v,{}"}"#).unwrap();
        write_bench_section(&path, "b", "[1, 2, 3]").unwrap();
        write_bench_section(&path, "a", r#"{"x": 2}"#).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        let sections = parse_sections(&s).unwrap();
        assert_eq!(sections.len(), 2);
        assert_eq!(sections[0], ("a".into(), r#"{"x": 2}"#.into()));
        assert_eq!(sections[1], ("b".into(), "[1, 2, 3]".into()));
        // Corrupt file degrades to empty, not an error.
        std::fs::write(&path, "not json").unwrap();
        write_bench_section(&path, "c", "7").unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            parse_sections(&s).unwrap(),
            vec![("c".into(), "7".into())]
        );
        let _ = std::fs::remove_file(&path);
    }
}
