//! Tiled integer sets: the iteration/condition spaces of statements after
//! LSGP tiling.
//!
//! After tiling (Eq. 3–6 of the paper), every statement is quantified over
//! the 2n-dimensional space of intra-tile coordinates `j = (j_0..j_{n-1})`
//! and tile origins `k = (k_0..k_{n-1})`, subject to constraints of the
//! forms
//!
//! * `0 ≤ j_ℓ < p_ℓ` (tile shape, Eq. 3),
//! * `0 ≤ k_ℓ < t_ℓ` (array extent, Eq. 4; `t_ℓ` fixed integers),
//! * `0 ≤ j_ℓ + p_ℓ·k_ℓ < N_ℓ` (global iteration-space membership),
//! * condition-space constraints affine in `i = j + P·k`, and
//! * `j − d_J − Pγ ∈ J` displacement constraints (Eq. 6).
//!
//! The term `p_ℓ·k_ℓ` makes constraints *bilinear* in (variables ×
//! parameters); we therefore represent each variable coefficient as an
//! [`AffineExpr`] over the parameters. Substituting a concrete `k` (the
//! paper's footnote-1 unfolding over the fixed array) collapses everything
//! back to parameter-affine bounds on each `j_ℓ`, which is what both the
//! concrete and the symbolic counters consume.

use std::fmt;

use super::expr::AffineExpr;

/// One constraint `Σ_v coeff_v(params)·var_v + konst(params) ≥ 0`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SetConstraint {
    /// Per-variable coefficient, parametric. Length = `2n` (j vars then k
    /// vars).
    pub var_coeffs: Vec<AffineExpr>,
    /// Constant (parametric) term.
    pub konst: AffineExpr,
}

impl SetConstraint {
    /// A constraint with all-zero coefficients (builder starting point).
    pub fn zero(nvars: usize, nparams: usize) -> Self {
        SetConstraint {
            var_coeffs: vec![AffineExpr::zero(nparams); nvars],
            konst: AffineExpr::zero(nparams),
        }
    }
}

/// A conjunction of [`SetConstraint`]s over `j`/`k` variables.
///
/// Variable layout: indices `0..n` are `j_0..j_{n-1}`, indices `n..2n` are
/// `k_0..k_{n-1}`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TiledSet {
    /// Loop depth `n` (the set has `2n` variables).
    pub ndims: usize,
    /// Number of symbolic parameters.
    pub nparams: usize,
    /// The constraints.
    pub constraints: Vec<SetConstraint>,
}

/// Bounds on a single `j` dimension after substituting a concrete `k`:
/// `max(lowers) ≤ j_ℓ ≤ min(uppers)`, all bounds parameter-affine.
#[derive(Debug, Clone, Default)]
pub struct DimBounds {
    pub lowers: Vec<AffineExpr>,
    pub uppers: Vec<AffineExpr>,
}

/// Result of substituting a concrete tile origin `k` into a [`TiledSet`]:
/// separable per-`j`-dimension bounds plus parameter-only conditions.
#[derive(Debug, Clone)]
pub struct UnfoldedCell {
    /// Per-dimension bounds on `j_0..j_{n-1}`.
    pub dims: Vec<DimBounds>,
    /// Constraints involving no variables: must hold for the cell to be
    /// non-empty (become chamber conditions of the symbolic count).
    pub param_conds: Vec<AffineExpr>,
}

/// Error for sets outside the separable class the counter supports.
#[derive(Debug)]
pub enum SetError {
    NonSeparable(usize, usize),
    NonUnitCoeff(usize, AffineExpr),
}

impl std::fmt::Display for SetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SetError::NonSeparable(a, b) => write!(
                f,
                "constraint couples multiple j-variables after \
                 k-substitution; the separable counter only supports the \
                 tiled-statement class (constraint touches j{a} and j{b})"
            ),
            SetError::NonUnitCoeff(l, c) => write!(
                f,
                "j{l} has parametric coefficient {c:?}; only constant ±1 \
                 coefficients are supported after k-substitution"
            ),
        }
    }
}

impl std::error::Error for SetError {}

impl TiledSet {
    /// An unconstrained set of loop depth `n`.
    pub fn universe(ndims: usize, nparams: usize) -> Self {
        TiledSet { ndims, nparams, constraints: Vec::new() }
    }

    /// Variable index of `j_ℓ`.
    pub fn jvar(&self, l: usize) -> usize {
        debug_assert!(l < self.ndims);
        l
    }

    /// Variable index of `k_ℓ`.
    pub fn kvar(&self, l: usize) -> usize {
        debug_assert!(l < self.ndims);
        self.ndims + l
    }

    fn nvars(&self) -> usize {
        2 * self.ndims
    }

    /// Add a raw constraint.
    pub fn add(&mut self, c: SetConstraint) {
        debug_assert_eq!(c.var_coeffs.len(), self.nvars());
        self.constraints.push(c);
    }

    /// Add `j_ℓ ≥ 0` and `j_ℓ ≤ p_ℓ − 1` (tile shape, Eq. 3), where `p_ℓ`
    /// is parameter index `p_idx`.
    pub fn add_tile_bounds(&mut self, l: usize, p_idx: usize) {
        let nv = self.nvars();
        let np = self.nparams;
        // j_l >= 0
        let mut lo = SetConstraint::zero(nv, np);
        lo.var_coeffs[self.jvar(l)] = AffineExpr::constant(np, 1);
        self.add(lo);
        // -j_l + p_l - 1 >= 0
        let mut hi = SetConstraint::zero(nv, np);
        hi.var_coeffs[self.jvar(l)] = AffineExpr::constant(np, -1);
        hi.konst = AffineExpr::param(np, p_idx).plus(-1);
        self.add(hi);
    }

    /// Add `0 ≤ k_ℓ ≤ t_ℓ − 1` (array extent, Eq. 4) with fixed `t_ℓ`.
    pub fn add_array_bounds(&mut self, l: usize, t_l: i64) {
        let nv = self.nvars();
        let np = self.nparams;
        let mut lo = SetConstraint::zero(nv, np);
        lo.var_coeffs[self.kvar(l)] = AffineExpr::constant(np, 1);
        self.add(lo);
        let mut hi = SetConstraint::zero(nv, np);
        hi.var_coeffs[self.kvar(l)] = AffineExpr::constant(np, -1);
        hi.konst = AffineExpr::constant(np, t_l - 1);
        self.add(hi);
    }

    /// Add a constraint affine in the *global* iteration vector
    /// `i = j + P·k`:  `Σ a_ℓ·i_ℓ + c ≥ 0` becomes
    /// `Σ a_ℓ·j_ℓ + Σ (a_ℓ·p_ℓ)·k_ℓ + c ≥ 0`.
    ///
    /// `konst` may itself be parametric (e.g. `N_ℓ − 1` for upper loop
    /// bounds); `p_idx[ℓ]` gives the parameter index of `p_ℓ`.
    pub fn add_global_affine(
        &mut self,
        a: &[i64],
        konst: AffineExpr,
        p_idx: &[usize],
    ) {
        debug_assert_eq!(a.len(), self.ndims);
        let nv = self.nvars();
        let np = self.nparams;
        let mut c = SetConstraint::zero(nv, np);
        for l in 0..self.ndims {
            if a[l] != 0 {
                c.var_coeffs[self.jvar(l)] = AffineExpr::constant(np, a[l]);
                c.var_coeffs[self.kvar(l)] =
                    AffineExpr::param_scaled(np, p_idx[l], a[l], 0);
            }
        }
        c.konst = konst;
        self.add(c);
    }

    /// Add `0 ≤ j_ℓ − off_ℓ ≤ p_ℓ − 1` membership constraints (the
    /// `j − d_J − Pγ ∈ J` displacement of Eq. 6), where `off` is a
    /// parameter-affine offset per dimension.
    pub fn add_shifted_tile_membership(
        &mut self,
        l: usize,
        off: AffineExpr,
        p_idx: usize,
    ) {
        let nv = self.nvars();
        let np = self.nparams;
        // j_l - off >= 0
        let mut lo = SetConstraint::zero(nv, np);
        lo.var_coeffs[self.jvar(l)] = AffineExpr::constant(np, 1);
        lo.konst = -&off;
        self.add(lo);
        // -(j_l - off) + p_l - 1 >= 0
        let mut hi = SetConstraint::zero(nv, np);
        hi.var_coeffs[self.jvar(l)] = AffineExpr::constant(np, -1);
        hi.konst = (&off + &AffineExpr::param(np, p_idx)).plus(-1);
        self.add(hi);
    }

    /// Substitute a concrete tile origin `k`, producing separable bounds on
    /// each `j` dimension (or an error if the set is outside the supported
    /// class).
    pub fn substitute_k(&self, k: &[i64]) -> Result<UnfoldedCell, SetError> {
        debug_assert_eq!(k.len(), self.ndims);
        let mut dims = vec![DimBounds::default(); self.ndims];
        let mut param_conds = Vec::new();
        'constraints: for c in &self.constraints {
            // Residual constant after substituting k values: fused
            // multiply-add into one clone (the unfold loop runs per
            // constraint per k-cell — no temporary expressions here).
            let mut resid = c.konst.clone();
            for l in 0..self.ndims {
                if k[l] != 0 {
                    let kc = &c.var_coeffs[self.kvar(l)];
                    for (r, &x) in resid.coeffs.iter_mut().zip(&kc.coeffs) {
                        *r += x * k[l];
                    }
                    resid.konst += kc.konst * k[l];
                } // k[l] == 0: term vanishes regardless of coefficient
            }
            // Which j variables remain?
            let mut touched: Option<usize> = None;
            for l in 0..self.ndims {
                let jc = &c.var_coeffs[self.jvar(l)];
                match jc.as_const() {
                    Some(0) => continue,
                    Some(a) if a == 1 || a == -1 => match touched {
                        None => touched = Some(l),
                        Some(prev) => {
                            return Err(SetError::NonSeparable(prev, l))
                        }
                    },
                    _ => {
                        return Err(SetError::NonUnitCoeff(l, jc.clone()));
                    }
                }
            }
            match touched {
                None => {
                    // Pure parameter condition; skip syntactic tautologies.
                    if resid.as_const().map(|v| v >= 0) == Some(true) {
                        continue 'constraints;
                    }
                    param_conds.push(resid);
                }
                Some(l) => {
                    let a = c.var_coeffs[self.jvar(l)].as_const().unwrap();
                    if a == 1 {
                        // j_l + resid >= 0  →  j_l >= -resid
                        dims[l].lowers.push(-&resid);
                    } else {
                        // -j_l + resid >= 0  →  j_l <= resid
                        dims[l].uppers.push(resid);
                    }
                }
            }
        }
        // Every dimension needs at least one bound on each side to have a
        // finite count; the tile-shape bounds guarantee this for sets built
        // through the tiling path. Add trivial j>=0 style guards otherwise?
        // No: report empty-side dimensions as unbounded by leaving the
        // lists empty — the counters treat that as an error via panic in
        // debug; production sets always carry Eq. 3 bounds.
        Ok(UnfoldedCell { dims, param_conds })
    }

    /// Brute-force membership test at fully concrete `(j, k, params)` —
    /// evaluates every constraint. Test oracle only.
    pub fn contains(&self, j: &[i64], k: &[i64], params: &[i64]) -> bool {
        self.constraints.iter().all(|c| {
            let mut acc = c.konst.eval(params) as i128;
            for l in 0..self.ndims {
                acc += c.var_coeffs[self.jvar(l)].eval(params) as i128
                    * j[l] as i128;
                acc += c.var_coeffs[self.kvar(l)].eval(params) as i128
                    * k[l] as i128;
            }
            acc >= 0
        })
    }
}

impl fmt::Display for TiledSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "TiledSet(n={}, {} constraints)",
            self.ndims,
            self.constraints.len()
        )
    }
}

/// Iterate over all tile origins `k ∈ [0,t_0)×…×[0,t_{n-1})`.
pub fn k_grid(t: &[i64]) -> Vec<Vec<i64>> {
    let mut out = vec![vec![]];
    for &tl in t {
        let mut next = Vec::with_capacity(out.len() * tl as usize);
        for base in &out {
            for v in 0..tl {
                let mut b = base.clone();
                b.push(v);
                next.push(b);
            }
        }
        out = next;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::expr::ParamSpace;

    /// Build the tiled GESUMMV-style base space of Example 2:
    /// n=2, params (N0,N1,p0,p1), array t0=t1=2,
    /// constraints: 0≤j<p, 0≤k<t, 0≤j+pk<N.
    fn base_space() -> (ParamSpace, TiledSet) {
        let sp = ParamSpace::loop_nest(2);
        let np = sp.len();
        let mut set = TiledSet::universe(2, np);
        for l in 0..2 {
            set.add_tile_bounds(l, sp.p_index(l));
            set.add_array_bounds(l, 2);
        }
        // 0 <= i_l  and  i_l <= N_l - 1
        for l in 0..2 {
            let mut a = [0i64; 2];
            a[l] = 1;
            set.add_global_affine(
                &a,
                AffineExpr::zero(np),
                &[sp.p_index(0), sp.p_index(1)],
            );
            let mut an = [0i64; 2];
            an[l] = -1;
            set.add_global_affine(
                &an,
                AffineExpr::param(np, sp.n_index(l)).plus(-1),
                &[sp.p_index(0), sp.p_index(1)],
            );
        }
        (sp, set)
    }

    #[test]
    fn k_grid_order_and_size() {
        let g = k_grid(&[2, 3]);
        assert_eq!(g.len(), 6);
        assert_eq!(g[0], vec![0, 0]);
        assert_eq!(g[5], vec![1, 2]);
    }

    #[test]
    fn contains_matches_example2_geometry() {
        // N0=4, N1=5, p0=2, p1=3 (Fig. 2): iteration (i0,i1)=(3,4) lives in
        // tile k=(1,1), j=(1,1).
        let (_, set) = base_space();
        let params = [4, 5, 2, 3];
        assert!(set.contains(&[1, 1], &[1, 1], &params));
        // j out of tile:
        assert!(!set.contains(&[2, 0], &[0, 0], &params));
        // i = j + P k = (0, 3+3) = (0,6) out of N1=5:
        assert!(!set.contains(&[0, 3], &[0, 1], &params));
    }

    #[test]
    fn substitute_k_produces_separable_bounds() {
        let (_, set) = base_space();
        let cell = set.substitute_k(&[1, 1]).unwrap();
        assert_eq!(cell.dims.len(), 2);
        // Each j dim: lowers from j>=0 and 0<=j+pk (k=1: j >= -p), uppers
        // from j<=p-1 and j+pk<=N-1 (j <= N-1-p).
        assert_eq!(cell.dims[0].lowers.len(), 2);
        assert_eq!(cell.dims[0].uppers.len(), 2);
        // No pure-param conditions for the base space at this k (k-bounds
        // are constant-true after substitution).
        assert!(cell.param_conds.is_empty());
    }

    #[test]
    fn substitute_k_shifted_membership() {
        // Add Eq.6-style shifted membership j1 - 1 ∈ [0, p1-1] (the S7*1
        // displacement of Example 2) and check the extra bounds appear.
        let (sp, mut set) = base_space();
        let np = sp.len();
        set.add_shifted_tile_membership(
            1,
            AffineExpr::constant(np, 1),
            sp.p_index(1),
        );
        let cell = set.substitute_k(&[0, 0]).unwrap();
        assert_eq!(cell.dims[1].lowers.len(), 3); // j1>=0, j1>=-p1k1(=0), j1>=1
        assert_eq!(cell.dims[1].uppers.len(), 3);
    }

    #[test]
    fn non_separable_rejected() {
        let sp = ParamSpace::loop_nest(2);
        let np = sp.len();
        let mut set = TiledSet::universe(2, np);
        // j0 + j1 >= 0 couples two j variables.
        let mut c = SetConstraint::zero(4, np);
        c.var_coeffs[0] = AffineExpr::constant(np, 1);
        c.var_coeffs[1] = AffineExpr::constant(np, 1);
        set.add(c);
        assert!(matches!(
            set.substitute_k(&[0, 0]),
            Err(SetError::NonSeparable(0, 1))
        ));
    }

    #[test]
    fn non_unit_coeff_rejected() {
        let sp = ParamSpace::loop_nest(2);
        let np = sp.len();
        let mut set = TiledSet::universe(2, np);
        let mut c = SetConstraint::zero(4, np);
        c.var_coeffs[0] = AffineExpr::constant(np, 2);
        set.add(c);
        assert!(matches!(
            set.substitute_k(&[0, 0]),
            Err(SetError::NonUnitCoeff(0, _))
        ));
    }
}
