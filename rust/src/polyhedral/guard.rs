//! Chamber guards: conjunctions of affine constraints over the parameters,
//! with **interned** constraints.
//!
//! The symbolic volume of a tiled statement space is piecewise polynomial:
//! each piece is valid on a *chamber* of the parameter space described by a
//! [`Guard`] — a conjunction of `expr ≥ 0` constraints (cf. the case
//! conditions like `2p1 < N1` in Example 9 of the paper). Feasibility and
//! redundancy of guards are decided by rational Fourier–Motzkin elimination,
//! which is conservative in the right direction: a rationally infeasible
//! system has no integer points either.
//!
//! # Interning
//!
//! Every [`Constraint`] is canonicalized (gcd-normalized with integer
//! tightening, see [`Constraint::ge0`]) and interned in the process-wide
//! [`ConstraintPool`], which maps each distinct constraint to a stable
//! `u32` id backed by a leaked (`&'static`) allocation. A [`Guard`] is then
//! just a small **sorted vector of ids** plus a cached constant-falsity
//! flag:
//!
//! * `and` / `and_guard` are O(n) integer merges — no expression clones;
//! * equality, hashing and ordering are integer operations, which makes
//!   guards cheap keys for the Fourier–Motzkin feasibility cache
//!   ([`super::symbolic::SymbolicCtx`]) shared across cells, statements
//!   and DSE points;
//! * [`Guard::simplified`]'s probe loop shuffles ids and `&'static`
//!   references instead of cloning constraint vectors.
//!
//! The pool only ever grows (ids are never invalidated); its size is
//! bounded by the number of *distinct canonical* constraints, which is tiny
//! in practice — bounds differ by constant shifts that normalize
//! identically.

use std::collections::HashMap;
use std::fmt;
use std::sync::{OnceLock, RwLock, RwLockReadGuard};

use super::expr::{gcd_u64, AffineExpr, ParamSpace};

/// A single constraint `expr ≥ 0` over the parameters.
///
/// Constraints are kept gcd-normalized so syntactic deduplication works.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Constraint(pub AffineExpr);

impl Constraint {
    /// `expr ≥ 0`, normalized.
    pub fn ge0(mut expr: AffineExpr) -> Self {
        // Integer tightening: for a ≥ 0 constraint we may divide the
        // parameter coefficients by their gcd g and floor the constant:
        // g·x + k ≥ 0  ⟺  x ≥ -k/g  ⟺  x ≥ ceil(-k/g)  ⟺  x + floor(k/g) ≥ 0.
        let g = {
            let mut g: u64 = 0;
            for &c in &expr.coeffs {
                g = gcd_u64(g, c.unsigned_abs());
            }
            g
        };
        if g > 1 {
            let g = g as i64;
            for c in &mut expr.coeffs {
                *c /= g;
            }
            expr.konst = expr.konst.div_euclid(g);
        }
        Constraint(expr)
    }

    /// `a ≥ b`, i.e. `a - b ≥ 0`.
    pub fn ge(a: &AffineExpr, b: &AffineExpr) -> Self {
        Constraint::ge0(a - b)
    }

    /// `a > b` over integers, i.e. `a - b - 1 ≥ 0`.
    pub fn gt(a: &AffineExpr, b: &AffineExpr) -> Self {
        Constraint::ge0((a - b).plus(-1))
    }

    /// `a ≤ b`.
    pub fn le(a: &AffineExpr, b: &AffineExpr) -> Self {
        Constraint::ge0(b - a)
    }

    /// `a < b` over integers.
    pub fn lt(a: &AffineExpr, b: &AffineExpr) -> Self {
        Constraint::ge0((b - a).plus(-1))
    }

    /// The negation `¬(expr ≥ 0)` = `-expr - 1 ≥ 0` (integer complement).
    pub fn negated(&self) -> Self {
        Constraint::ge0((-&self.0).plus(-1))
    }

    /// True / false when the constraint is constant.
    pub fn as_const(&self) -> Option<bool> {
        self.0.as_const().map(|c| c >= 0)
    }

    /// Evaluate at a concrete parameter point (sign-only `i128`
    /// arithmetic — cannot overflow for `i64` parameters).
    pub fn holds(&self, params: &[i64]) -> bool {
        self.0.nonneg_at(params)
    }

    /// Pretty-print as `expr >= 0` with parameter names.
    pub fn display<'a>(&'a self, space: &'a ParamSpace) -> ConstraintDisplay<'a> {
        ConstraintDisplay { c: self, space }
    }
}

/// Formatting helper for [`Constraint`].
pub struct ConstraintDisplay<'a> {
    c: &'a Constraint,
    space: &'a ParamSpace,
}

impl fmt::Display for ConstraintDisplay<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} >= 0", self.c.0.display(self.space))
    }
}

/// Stable id of an interned [`Constraint`].
pub type ConstraintId = u32;

#[derive(Default)]
struct PoolInner {
    ids: HashMap<&'static Constraint, ConstraintId>,
    items: Vec<&'static Constraint>,
}

fn pool() -> &'static RwLock<PoolInner> {
    static POOL: OnceLock<RwLock<PoolInner>> = OnceLock::new();
    POOL.get_or_init(|| RwLock::new(PoolInner::default()))
}

/// Read view over the interner. Never hold one across a call that may
/// intern (interning takes the write lock).
pub(crate) struct PoolRead(RwLockReadGuard<'static, PoolInner>);

impl PoolRead {
    pub(crate) fn get(&self, id: ConstraintId) -> &'static Constraint {
        self.0.items[id as usize]
    }
}

/// Acquire a read view of the global pool (cheap, shared).
pub(crate) fn pool_read() -> PoolRead {
    PoolRead(pool().read().unwrap())
}

/// The process-wide constraint interner. Canonical constraints map to
/// stable `u32` ids; resolved references are `&'static` (the entries are
/// leaked — the pool is append-only and bounded by the number of distinct
/// canonical constraints ever built).
pub struct ConstraintPool;

impl ConstraintPool {
    /// Intern `c`, returning its stable id. Read-locked fast path for the
    /// (overwhelmingly common) already-interned case.
    pub fn intern(c: Constraint) -> ConstraintId {
        {
            let inner = pool().read().unwrap();
            if let Some(&id) = inner.ids.get(&c) {
                return id;
            }
        }
        let mut inner = pool().write().unwrap();
        if let Some(&id) = inner.ids.get(&c) {
            return id; // raced: another thread interned it first
        }
        let id = ConstraintId::try_from(inner.items.len())
            .expect("constraint pool overflow");
        let leaked: &'static Constraint = Box::leak(Box::new(c));
        inner.ids.insert(leaked, id);
        inner.items.push(leaked);
        id
    }

    /// Resolve an id to its constraint.
    pub fn get(id: ConstraintId) -> &'static Constraint {
        pool_read().get(id)
    }

    /// Number of distinct constraints interned so far.
    pub fn len() -> usize {
        pool().read().unwrap().items.len()
    }
}

/// A conjunction of constraints describing a parameter-space chamber,
/// stored as a sorted, deduplicated vector of interned constraint ids.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct Guard {
    /// Sorted, deduplicated ids (normal form).
    ids: Vec<ConstraintId>,
    /// Whether any member is a constant-false constraint (cached so the
    /// hot feasibility path needs no pool access).
    is_false: bool,
}

impl Guard {
    /// The trivially-true guard.
    pub fn always() -> Self {
        Guard::default()
    }

    /// Build from constraints, normalizing (constant-true members are
    /// dropped, duplicates merged).
    pub fn new(constraints: Vec<Constraint>) -> Self {
        let mut ids = Vec::with_capacity(constraints.len());
        let mut is_false = false;
        for c in constraints {
            match c.as_const() {
                Some(true) => continue,
                Some(false) => is_false = true,
                None => {}
            }
            ids.push(ConstraintPool::intern(c));
        }
        ids.sort_unstable();
        ids.dedup();
        Guard { ids, is_false }
    }

    /// Conjunction with one more constraint.
    pub fn and(&self, c: Constraint) -> Guard {
        let truth = c.as_const();
        if truth == Some(true) {
            return self.clone();
        }
        let id = ConstraintPool::intern(c);
        match self.ids.binary_search(&id) {
            Ok(_) => self.clone(),
            Err(pos) => {
                let mut ids = Vec::with_capacity(self.ids.len() + 1);
                ids.extend_from_slice(&self.ids[..pos]);
                ids.push(id);
                ids.extend_from_slice(&self.ids[pos..]);
                Guard {
                    ids,
                    is_false: self.is_false || truth == Some(false),
                }
            }
        }
    }

    /// Conjunction of two guards: a sorted integer merge, no expression
    /// traffic at all.
    pub fn and_guard(&self, other: &Guard) -> Guard {
        let (a, b) = (&self.ids, &other.ids);
        let mut ids = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.len() && j < b.len() {
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => {
                    ids.push(a[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    ids.push(b[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    ids.push(a[i]);
                    i += 1;
                    j += 1;
                }
            }
        }
        ids.extend_from_slice(&a[i..]);
        ids.extend_from_slice(&b[j..]);
        Guard { ids, is_false: self.is_false || other.is_false }
    }

    /// Number of constraints.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    /// True for the trivially-true guard.
    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// The sorted interned ids (crate-internal: chamber decomposition
    /// works directly on ids).
    pub(crate) fn ids(&self) -> &[ConstraintId] {
        &self.ids
    }

    /// Whether the guard contains the constraint with this id.
    pub(crate) fn contains_id(&self, id: ConstraintId) -> bool {
        self.ids.binary_search(&id).is_ok()
    }

    /// Resolve to the member constraints, in id order.
    pub fn resolved(&self) -> Vec<&'static Constraint> {
        let pool = pool_read();
        self.ids.iter().map(|&id| pool.get(id)).collect()
    }

    /// Member constraints sorted by content — the canonical cross-process
    /// order (ids are assigned in interning order, which may vary).
    pub(crate) fn sort_key(
        &self,
        pool: &PoolRead,
    ) -> Vec<&'static Constraint> {
        let mut v: Vec<&'static Constraint> =
            self.ids.iter().map(|&id| pool.get(id)).collect();
        v.sort_unstable();
        v
    }

    /// Contains a syntactically-false constraint?
    pub fn has_false(&self) -> bool {
        self.is_false
    }

    /// Evaluate at a concrete parameter point.
    pub fn holds(&self, params: &[i64]) -> bool {
        if self.is_false {
            return false;
        }
        let pool = pool_read();
        self.holds_in(&pool, params)
    }

    /// As [`Self::holds`] with a caller-held pool view (the batched form
    /// used by `GuardedSum::eval`, which checks many guards per query).
    pub(crate) fn holds_in(&self, pool: &PoolRead, params: &[i64]) -> bool {
        self.ids.iter().all(|&id| pool.get(id).holds(params))
    }

    /// Rational feasibility via Fourier–Motzkin. `false` means *certainly*
    /// empty (also over the integers); `true` means rationally non-empty.
    pub fn feasible(&self) -> bool {
        if self.is_false {
            return false;
        }
        fm_feasible(&self.resolved())
    }

    /// Remove constraints implied by the rest (within `context`), producing
    /// a minimal readable guard. A constraint `c` is redundant iff
    /// `rest ∧ context ∧ ¬c` is infeasible. Probes run in content order,
    /// so the chosen minimal subset is stable across processes regardless
    /// of interning order; the loop shuffles ids and `&'static` references
    /// only — no expression clones.
    pub fn simplified(&self, context: &Guard) -> Guard {
        let ctx_refs: Vec<&'static Constraint> = context.resolved();
        let mut kept: Vec<(ConstraintId, &'static Constraint)> = {
            let pool = pool_read();
            let mut v: Vec<(ConstraintId, &'static Constraint)> =
                self.ids.iter().map(|&id| (id, pool.get(id))).collect();
            v.sort_by(|a, b| a.1.cmp(b.1));
            v
        };
        let mut i = 0;
        while i < kept.len() {
            let neg = kept[i].1.negated();
            let mut probe: Vec<&Constraint> = kept
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i)
                .map(|(_, &(_, c))| c)
                .collect();
            probe.extend(ctx_refs.iter().copied());
            probe.push(&neg);
            if !fm_feasible(&probe) {
                kept.remove(i); // implied: drop
            } else {
                i += 1;
            }
        }
        let mut ids: Vec<ConstraintId> =
            kept.iter().map(|&(id, _)| id).collect();
        ids.sort_unstable();
        let is_false =
            kept.iter().any(|&(_, c)| c.as_const() == Some(false));
        Guard { ids, is_false }
    }

    /// Pretty-print as ` a ∧ b ∧ …` using `<=`/`<`-style inequalities.
    pub fn display<'a>(&'a self, space: &'a ParamSpace) -> GuardDisplay<'a> {
        GuardDisplay { g: self, space }
    }
}

/// Formatting helper for [`Guard`]. Prints members in content order
/// (stable across processes regardless of interning order).
pub struct GuardDisplay<'a> {
    g: &'a Guard,
    space: &'a ParamSpace,
}

impl fmt::Display for GuardDisplay<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.g.ids.is_empty() {
            return write!(f, "true");
        }
        let mut cs = self.g.resolved();
        cs.sort_unstable();
        for (i, c) in cs.iter().enumerate() {
            if i > 0 {
                write!(f, " and ")?;
            }
            write!(f, "{}", c.display(self.space))?;
        }
        Ok(())
    }
}

/// Rational feasibility of `{x : e_i(x) ≥ 0}` by Fourier–Motzkin
/// elimination with i128 arithmetic and gcd reduction at every step.
pub(crate) fn fm_feasible(constraints: &[&Constraint]) -> bool {
    if constraints.is_empty() {
        return true;
    }
    let nparams = constraints[0].0.nparams();
    // Represent each constraint as (coeffs: Vec<i128>, konst: i128).
    let mut sys: Vec<(Vec<i128>, i128)> = constraints
        .iter()
        .map(|c| {
            (
                c.0.coeffs.iter().map(|&x| x as i128).collect(),
                c.0.konst as i128,
            )
        })
        .collect();

    for var in 0..nparams {
        let mut lowers: Vec<(Vec<i128>, i128)> = Vec::new(); // coeff > 0
        let mut uppers: Vec<(Vec<i128>, i128)> = Vec::new(); // coeff < 0
        let mut rest: Vec<(Vec<i128>, i128)> = Vec::new();
        for (c, k) in sys.drain(..) {
            match c[var].signum() {
                1 => lowers.push((c, k)),
                -1 => uppers.push((c, k)),
                _ => rest.push((c, k)),
            }
        }
        // Combine every (lower, upper) pair to eliminate `var`.
        for (lc, lk) in &lowers {
            for (uc, uk) in &uppers {
                let a = lc[var]; // > 0
                let b = -uc[var]; // > 0
                // b·lower + a·upper  eliminates var.
                let mut nc: Vec<i128> = (0..nparams)
                    .map(|i| b * lc[i] + a * uc[i])
                    .collect();
                let mut nk = b * lk + a * uk;
                debug_assert_eq!(nc[var], 0);
                // gcd-reduce to keep numbers small
                let mut g: u128 = nk.unsigned_abs();
                for &x in &nc {
                    g = gcd_u128(g, x.unsigned_abs());
                }
                if g > 1 {
                    let g = g as i128;
                    nk /= g;
                    for x in &mut nc {
                        *x /= g;
                    }
                }
                if nc.iter().all(|&x| x == 0) {
                    if nk < 0 {
                        return false; // 0 ≥ positive: contradiction
                    }
                } else {
                    rest.push((nc, nk));
                }
            }
        }
        // Dedup to curb FM blowup.
        rest.sort();
        rest.dedup();
        sys = rest;
        if sys.is_empty() {
            return true;
        }
    }
    // All variables eliminated: remaining constraints are constants.
    sys.iter().all(|(_, k)| *k >= 0)
}

fn gcd_u128(mut a: u128, mut b: u128) -> u128 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp() -> ParamSpace {
        ParamSpace::loop_nest(1) // N0, p0
    }

    fn n0(s: &ParamSpace) -> AffineExpr {
        AffineExpr::param(s.len(), 0)
    }
    fn p0(s: &ParamSpace) -> AffineExpr {
        AffineExpr::param(s.len(), 1)
    }
    fn k(s: &ParamSpace, c: i64) -> AffineExpr {
        AffineExpr::constant(s.len(), c)
    }

    #[test]
    fn constraint_relations() {
        let s = sp();
        // N0 > p0 at (5,3): 5-3-1 = 1 >= 0 holds
        assert!(Constraint::gt(&n0(&s), &p0(&s)).holds(&[5, 3]));
        assert!(!Constraint::gt(&n0(&s), &p0(&s)).holds(&[3, 3]));
        assert!(Constraint::le(&p0(&s), &n0(&s)).holds(&[3, 3]));
        assert!(Constraint::lt(&p0(&s), &n0(&s)).holds(&[4, 3]));
    }

    #[test]
    fn negation_is_integer_complement() {
        let s = sp();
        let c = Constraint::ge(&n0(&s), &k(&s, 5)); // N0 >= 5
        let nc = c.negated(); // N0 <= 4
        for v in 0..10 {
            assert_eq!(c.holds(&[v, 0]), !nc.holds(&[v, 0]), "v={v}");
        }
    }

    #[test]
    fn interning_dedups_equal_constraints() {
        let s = sp();
        let a = ConstraintPool::intern(Constraint::ge(&n0(&s), &k(&s, 3)));
        let b = ConstraintPool::intern(Constraint::ge(&n0(&s), &k(&s, 3)));
        assert_eq!(a, b);
        assert_eq!(
            *ConstraintPool::get(a),
            Constraint::ge(&n0(&s), &k(&s, 3))
        );
        assert!(ConstraintPool::len() >= 1);
    }

    #[test]
    fn guard_normalization_dedups() {
        let s = sp();
        let c = Constraint::ge(&n0(&s), &k(&s, 1));
        let g = Guard::new(vec![c.clone(), c.clone(), Constraint::ge0(k(&s, 7))]);
        // constant-true dropped, duplicate removed
        assert_eq!(g.len(), 1);
    }

    #[test]
    fn guard_equality_is_order_insensitive() {
        let s = sp();
        let a = Constraint::ge(&n0(&s), &k(&s, 2));
        let b = Constraint::ge(&p0(&s), &k(&s, 1));
        let g1 = Guard::new(vec![a.clone(), b.clone()]);
        let g2 = Guard::new(vec![b.clone()]).and(a.clone());
        let g3 = Guard::new(vec![a]).and_guard(&Guard::new(vec![b]));
        assert_eq!(g1, g2);
        assert_eq!(g1, g3);
    }

    #[test]
    fn has_false_flag_tracks_constant_falsity() {
        let s = sp();
        let t = Guard::new(vec![Constraint::ge(&n0(&s), &k(&s, 1))]);
        assert!(!t.has_false());
        let f = t.and(Constraint::ge0(k(&s, -3)));
        assert!(f.has_false());
        assert!(!f.feasible());
        assert!(!f.holds(&[5, 5]));
        // and_guard propagates the flag
        assert!(t.and_guard(&f).has_false());
    }

    #[test]
    fn feasibility_basic() {
        let s = sp();
        // N0 >= 5 and N0 <= 3 -> infeasible
        let g = Guard::new(vec![
            Constraint::ge(&n0(&s), &k(&s, 5)),
            Constraint::le(&n0(&s), &k(&s, 3)),
        ]);
        assert!(!g.feasible());
        // N0 >= 5 and N0 <= 7 -> feasible
        let g2 = Guard::new(vec![
            Constraint::ge(&n0(&s), &k(&s, 5)),
            Constraint::le(&n0(&s), &k(&s, 7)),
        ]);
        assert!(g2.feasible());
    }

    #[test]
    fn feasibility_coupled() {
        let s = sp();
        // p0 >= 1, N0 >= 2*p0, N0 <= p0 -> infeasible (needs FM coupling)
        let two_p0 = &p0(&s) * 2;
        let g = Guard::new(vec![
            Constraint::ge(&p0(&s), &k(&s, 1)),
            Constraint::ge(&n0(&s), &two_p0),
            Constraint::le(&n0(&s), &p0(&s)),
        ]);
        assert!(!g.feasible());
    }

    #[test]
    fn integer_tightening_in_ge0() {
        let s = sp();
        // 2*N0 - 3 >= 0  ⟺ N0 >= 1.5 ⟺ N0 >= 2 over Z: tightened to N0 - 2 >= 0
        let c = Constraint::ge0(AffineExpr::param_scaled(s.len(), 0, 2, -3));
        assert!(!c.holds(&[1, 0]));
        assert!(c.holds(&[2, 0]));
        assert_eq!(c.0, AffineExpr::param_scaled(s.len(), 0, 1, -2));
    }

    #[test]
    fn simplify_drops_implied() {
        let s = sp();
        // context: p0 >= 1. guard: N0 >= 2p0 and N0 >= p0 (latter implied).
        let ctx = Guard::new(vec![Constraint::ge(&p0(&s), &k(&s, 1))]);
        let g = Guard::new(vec![
            Constraint::ge(&n0(&s), &(&p0(&s) * 2)),
            Constraint::ge(&n0(&s), &p0(&s)),
        ]);
        let simp = g.simplified(&ctx);
        assert_eq!(simp.len(), 1);
        assert_eq!(
            *simp.resolved()[0],
            Constraint::ge(&n0(&s), &(&p0(&s) * 2))
        );
    }

    #[test]
    fn guard_display() {
        let s = sp();
        let g = Guard::new(vec![Constraint::ge(&n0(&s), &k(&s, 1))]);
        assert_eq!(format!("{}", g.display(&s)), "N0 - 1 >= 0");
        assert_eq!(format!("{}", Guard::always().display(&s)), "true");
    }
}
