//! Chamber guards: conjunctions of affine constraints over the parameters.
//!
//! The symbolic volume of a tiled statement space is piecewise polynomial:
//! each piece is valid on a *chamber* of the parameter space described by a
//! [`Guard`] — a conjunction of `expr ≥ 0` constraints (cf. the case
//! conditions like `2p1 < N1` in Example 9 of the paper). Feasibility and
//! redundancy of guards are decided by rational Fourier–Motzkin elimination,
//! which is conservative in the right direction: a rationally infeasible
//! system has no integer points either.

use std::fmt;

use super::expr::{gcd_u64, AffineExpr, ParamSpace};

/// A single constraint `expr ≥ 0` over the parameters.
///
/// Constraints are kept gcd-normalized so syntactic deduplication works.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Constraint(pub AffineExpr);

impl Constraint {
    /// `expr ≥ 0`, normalized.
    pub fn ge0(mut expr: AffineExpr) -> Self {
        // Integer tightening: for a ≥ 0 constraint we may divide the
        // parameter coefficients by their gcd g and floor the constant:
        // g·x + k ≥ 0  ⟺  x ≥ -k/g  ⟺  x ≥ ceil(-k/g)  ⟺  x + floor(k/g) ≥ 0.
        let g = {
            let mut g: u64 = 0;
            for &c in &expr.coeffs {
                g = gcd_u64(g, c.unsigned_abs());
            }
            g
        };
        if g > 1 {
            let g = g as i64;
            for c in &mut expr.coeffs {
                *c /= g;
            }
            expr.konst = expr.konst.div_euclid(g);
        }
        Constraint(expr)
    }

    /// `a ≥ b`, i.e. `a - b ≥ 0`.
    pub fn ge(a: &AffineExpr, b: &AffineExpr) -> Self {
        Constraint::ge0(a - b)
    }

    /// `a > b` over integers, i.e. `a - b - 1 ≥ 0`.
    pub fn gt(a: &AffineExpr, b: &AffineExpr) -> Self {
        Constraint::ge0((a - b).plus(-1))
    }

    /// `a ≤ b`.
    pub fn le(a: &AffineExpr, b: &AffineExpr) -> Self {
        Constraint::ge0(b - a)
    }

    /// `a < b` over integers.
    pub fn lt(a: &AffineExpr, b: &AffineExpr) -> Self {
        Constraint::ge0((b - a).plus(-1))
    }

    /// The negation `¬(expr ≥ 0)` = `-expr - 1 ≥ 0` (integer complement).
    pub fn negated(&self) -> Self {
        Constraint::ge0((-&self.0).plus(-1))
    }

    /// True / false when the constraint is constant.
    pub fn as_const(&self) -> Option<bool> {
        self.0.as_const().map(|c| c >= 0)
    }

    /// Evaluate at a concrete parameter point.
    pub fn holds(&self, params: &[i64]) -> bool {
        self.0.eval(params) >= 0
    }

    /// Pretty-print as `expr >= 0` with parameter names.
    pub fn display<'a>(&'a self, space: &'a ParamSpace) -> ConstraintDisplay<'a> {
        ConstraintDisplay { c: self, space }
    }
}

/// Formatting helper for [`Constraint`].
pub struct ConstraintDisplay<'a> {
    c: &'a Constraint,
    space: &'a ParamSpace,
}

impl fmt::Display for ConstraintDisplay<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} >= 0", self.c.0.display(self.space))
    }
}

/// A conjunction of constraints describing a parameter-space chamber.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct Guard {
    /// Sorted, deduplicated constraint list (normal form).
    pub constraints: Vec<Constraint>,
}

impl Guard {
    /// The trivially-true guard.
    pub fn always() -> Self {
        Guard { constraints: Vec::new() }
    }

    /// Build from constraints, normalizing.
    pub fn new(mut constraints: Vec<Constraint>) -> Self {
        constraints.retain(|c| c.as_const() != Some(true));
        constraints.sort();
        constraints.dedup();
        Guard { constraints }
    }

    /// Conjunction with one more constraint.
    pub fn and(&self, c: Constraint) -> Guard {
        let mut cs = self.constraints.clone();
        cs.push(c);
        Guard::new(cs)
    }

    /// Conjunction of two guards.
    pub fn and_guard(&self, other: &Guard) -> Guard {
        let mut cs = self.constraints.clone();
        cs.extend(other.constraints.iter().cloned());
        Guard::new(cs)
    }

    /// Contains a syntactically-false constraint?
    pub fn has_false(&self) -> bool {
        self.constraints.iter().any(|c| c.as_const() == Some(false))
    }

    /// Evaluate at a concrete parameter point.
    pub fn holds(&self, params: &[i64]) -> bool {
        self.constraints.iter().all(|c| c.holds(params))
    }

    /// Rational feasibility via Fourier–Motzkin. `false` means *certainly*
    /// empty (also over the integers); `true` means rationally non-empty.
    pub fn feasible(&self) -> bool {
        if self.has_false() {
            return false;
        }
        fm_feasible(&self.constraints)
    }

    /// Remove constraints implied by the rest (within `context`), producing
    /// a minimal readable guard. A constraint `c` is redundant iff
    /// `rest ∧ context ∧ ¬c` is infeasible.
    pub fn simplified(&self, context: &Guard) -> Guard {
        let mut kept: Vec<Constraint> = self.constraints.clone();
        let mut i = 0;
        while i < kept.len() {
            let c = kept[i].clone();
            let mut probe: Vec<Constraint> = Vec::with_capacity(
                kept.len() + context.constraints.len(),
            );
            probe.extend(kept.iter().enumerate().filter(|(j, _)| *j != i).map(|(_, x)| x.clone()));
            probe.extend(context.constraints.iter().cloned());
            probe.push(c.negated());
            if !fm_feasible(&probe) {
                kept.remove(i); // implied: drop
            } else {
                i += 1;
            }
        }
        Guard::new(kept)
    }

    /// Pretty-print as ` a ∧ b ∧ …` using `<=`/`<`-style inequalities.
    pub fn display<'a>(&'a self, space: &'a ParamSpace) -> GuardDisplay<'a> {
        GuardDisplay { g: self, space }
    }
}

/// Formatting helper for [`Guard`].
pub struct GuardDisplay<'a> {
    g: &'a Guard,
    space: &'a ParamSpace,
}

impl fmt::Display for GuardDisplay<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.g.constraints.is_empty() {
            return write!(f, "true");
        }
        for (i, c) in self.g.constraints.iter().enumerate() {
            if i > 0 {
                write!(f, " and ")?;
            }
            write!(f, "{}", c.display(self.space))?;
        }
        Ok(())
    }
}

/// Rational feasibility of `{x : e_i(x) ≥ 0}` by Fourier–Motzkin
/// elimination with i128 arithmetic and gcd reduction at every step.
fn fm_feasible(constraints: &[Constraint]) -> bool {
    if constraints.is_empty() {
        return true;
    }
    let nparams = constraints[0].0.nparams();
    // Represent each constraint as (coeffs: Vec<i128>, konst: i128).
    let mut sys: Vec<(Vec<i128>, i128)> = constraints
        .iter()
        .map(|c| {
            (
                c.0.coeffs.iter().map(|&x| x as i128).collect(),
                c.0.konst as i128,
            )
        })
        .collect();

    for var in 0..nparams {
        let mut lowers: Vec<(Vec<i128>, i128)> = Vec::new(); // coeff > 0
        let mut uppers: Vec<(Vec<i128>, i128)> = Vec::new(); // coeff < 0
        let mut rest: Vec<(Vec<i128>, i128)> = Vec::new();
        for (c, k) in sys.drain(..) {
            match c[var].signum() {
                1 => lowers.push((c, k)),
                -1 => uppers.push((c, k)),
                _ => rest.push((c, k)),
            }
        }
        // Combine every (lower, upper) pair to eliminate `var`.
        for (lc, lk) in &lowers {
            for (uc, uk) in &uppers {
                let a = lc[var]; // > 0
                let b = -uc[var]; // > 0
                // b·lower + a·upper  eliminates var.
                let mut nc: Vec<i128> = (0..nparams)
                    .map(|i| b * lc[i] + a * uc[i])
                    .collect();
                let mut nk = b * lk + a * uk;
                debug_assert_eq!(nc[var], 0);
                // gcd-reduce to keep numbers small
                let mut g: u128 = nk.unsigned_abs();
                for &x in &nc {
                    g = gcd_u128(g, x.unsigned_abs());
                }
                if g > 1 {
                    let g = g as i128;
                    nk /= g;
                    for x in &mut nc {
                        *x /= g;
                    }
                }
                if nc.iter().all(|&x| x == 0) {
                    if nk < 0 {
                        return false; // 0 ≥ positive: contradiction
                    }
                } else {
                    rest.push((nc, nk));
                }
            }
        }
        // Dedup to curb FM blowup.
        rest.sort();
        rest.dedup();
        sys = rest;
        if sys.is_empty() {
            return true;
        }
    }
    // All variables eliminated: remaining constraints are constants.
    sys.iter().all(|(_, k)| *k >= 0)
}

fn gcd_u128(mut a: u128, mut b: u128) -> u128 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp() -> ParamSpace {
        ParamSpace::loop_nest(1) // N0, p0
    }

    fn n0(s: &ParamSpace) -> AffineExpr {
        AffineExpr::param(s.len(), 0)
    }
    fn p0(s: &ParamSpace) -> AffineExpr {
        AffineExpr::param(s.len(), 1)
    }
    fn k(s: &ParamSpace, c: i64) -> AffineExpr {
        AffineExpr::constant(s.len(), c)
    }

    #[test]
    fn constraint_relations() {
        let s = sp();
        // N0 > p0 at (5,3): 5-3-1 = 1 >= 0 holds
        assert!(Constraint::gt(&n0(&s), &p0(&s)).holds(&[5, 3]));
        assert!(!Constraint::gt(&n0(&s), &p0(&s)).holds(&[3, 3]));
        assert!(Constraint::le(&p0(&s), &n0(&s)).holds(&[3, 3]));
        assert!(Constraint::lt(&p0(&s), &n0(&s)).holds(&[4, 3]));
    }

    #[test]
    fn negation_is_integer_complement() {
        let s = sp();
        let c = Constraint::ge(&n0(&s), &k(&s, 5)); // N0 >= 5
        let nc = c.negated(); // N0 <= 4
        for v in 0..10 {
            assert_eq!(c.holds(&[v, 0]), !nc.holds(&[v, 0]), "v={v}");
        }
    }

    #[test]
    fn guard_normalization_dedups() {
        let s = sp();
        let c = Constraint::ge(&n0(&s), &k(&s, 1));
        let g = Guard::new(vec![c.clone(), c.clone(), Constraint::ge0(k(&s, 7))]);
        // constant-true dropped, duplicate removed
        assert_eq!(g.constraints.len(), 1);
    }

    #[test]
    fn feasibility_basic() {
        let s = sp();
        // N0 >= 5 and N0 <= 3 -> infeasible
        let g = Guard::new(vec![
            Constraint::ge(&n0(&s), &k(&s, 5)),
            Constraint::le(&n0(&s), &k(&s, 3)),
        ]);
        assert!(!g.feasible());
        // N0 >= 5 and N0 <= 7 -> feasible
        let g2 = Guard::new(vec![
            Constraint::ge(&n0(&s), &k(&s, 5)),
            Constraint::le(&n0(&s), &k(&s, 7)),
        ]);
        assert!(g2.feasible());
    }

    #[test]
    fn feasibility_coupled() {
        let s = sp();
        // p0 >= 1, N0 >= 2*p0, N0 <= p0 -> infeasible (needs FM coupling)
        let two_p0 = &p0(&s) * 2;
        let g = Guard::new(vec![
            Constraint::ge(&p0(&s), &k(&s, 1)),
            Constraint::ge(&n0(&s), &two_p0),
            Constraint::le(&n0(&s), &p0(&s)),
        ]);
        assert!(!g.feasible());
    }

    #[test]
    fn integer_tightening_in_ge0() {
        let s = sp();
        // 2*N0 - 3 >= 0  ⟺ N0 >= 1.5 ⟺ N0 >= 2 over Z: tightened to N0 - 2 >= 0
        let c = Constraint::ge0(AffineExpr::param_scaled(s.len(), 0, 2, -3));
        assert!(!c.holds(&[1, 0]));
        assert!(c.holds(&[2, 0]));
        assert_eq!(c.0, AffineExpr::param_scaled(s.len(), 0, 1, -2));
    }

    #[test]
    fn simplify_drops_implied() {
        let s = sp();
        // context: p0 >= 1. guard: N0 >= 2p0 and N0 >= p0 (latter implied).
        let ctx = Guard::new(vec![Constraint::ge(&p0(&s), &k(&s, 1))]);
        let g = Guard::new(vec![
            Constraint::ge(&n0(&s), &(&p0(&s) * 2)),
            Constraint::ge(&n0(&s), &p0(&s)),
        ]);
        let simp = g.simplified(&ctx);
        assert_eq!(simp.constraints.len(), 1);
        assert_eq!(simp.constraints[0], Constraint::ge(&n0(&s), &(&p0(&s) * 2)));
    }

    #[test]
    fn guard_display() {
        let s = sp();
        let g = Guard::new(vec![Constraint::ge(&n0(&s), &k(&s, 1))]);
        assert_eq!(format!("{}", g.display(&s)), "N0 - 1 >= 0");
        assert_eq!(format!("{}", Guard::always().display(&s)), "true");
    }
}
