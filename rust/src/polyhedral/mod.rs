//! Parametric polyhedral substrate: affine expressions, polynomials,
//! chamber guards, tiled integer sets, and exact + symbolic lattice-point
//! counting (the in-repo ISL/Barvinok substitute — see DESIGN.md §4).

pub mod count;
pub mod expr;
pub mod guard;
pub mod piecewise;
pub mod poly;
pub mod set;
pub mod symbolic;

pub use count::{count_bruteforce, count_concrete};
pub use expr::{AffineExpr, ParamSpace};
pub use guard::{Constraint, ConstraintId, ConstraintPool, Guard};
pub use piecewise::{GuardedSum, PiecewiseQPoly};
pub use poly::Poly;
pub use set::{k_grid, DimBounds, SetConstraint, SetError, TiledSet, UnfoldedCell};
pub use symbolic::{
    check_point_guard, count_symbolic, count_symbolic_in, set_point_guard,
    FeasPool, FeasStats, PointGuard, SymbolicCtx, SymbolicOptions,
    POINT_CANCELLED_PANIC, POINT_TIMEOUT_PANIC,
};
