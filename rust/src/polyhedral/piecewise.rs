//! Piecewise quasi-polynomials over parameter chambers.
//!
//! Two representations are used:
//!
//! * [`GuardedSum`] — a *sum* of guarded polynomials: the value at a
//!   parameter point is the sum of all pieces whose guard holds. This is
//!   what the symbolic counter naturally produces (one batch of pieces per
//!   unfolded processor index `k`) and is the cheap-to-evaluate form.
//! * [`PiecewiseQPoly`] — a *disjoint case expression*, exactly the shape
//!   the paper prints in Example 9 (`4p0(p1-1) if …, 2N0(p1-1) if …, …`).
//!   Obtained from a [`GuardedSum`] by chamber decomposition.
//!
//! Guards are interned id vectors (see [`super::guard`]): merging pieces
//! hashes small integer keys, and evaluation resolves all guards of a sum
//! under a single shared pool view.

use std::collections::HashMap;
use std::fmt;

use super::expr::ParamSpace;
use super::guard::{self, Constraint, Guard};
use super::poly::Poly;
use super::symbolic::SymbolicCtx;

/// Additive collection of guarded polynomials: `value(x) = Σ {poly_i(x) :
/// guard_i(x) holds}`.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct GuardedSum {
    nparams: usize,
    pub pieces: Vec<(Guard, Poly)>,
}

impl GuardedSum {
    /// The zero sum.
    pub fn zero(nparams: usize) -> Self {
        GuardedSum { nparams, pieces: Vec::new() }
    }

    /// A single unconditional polynomial.
    pub fn unconditional(poly: Poly) -> Self {
        let nparams = poly.nparams();
        GuardedSum { nparams, pieces: vec![(Guard::always(), poly)] }
    }

    /// Number of parameters.
    pub fn nparams(&self) -> usize {
        self.nparams
    }

    /// Add one guarded piece (dropping zero polynomials and infeasible
    /// guards early).
    pub fn push(&mut self, guard: Guard, poly: Poly) {
        if poly.is_zero() || guard.has_false() {
            return;
        }
        self.pieces.push((guard, poly));
    }

    /// Merge pieces with *identical guards* (cheap syntactic compaction —
    /// the symbolic counter benefits a lot because many `k`-cells produce
    /// the same chamber conditions). Guards hash as small id vectors, so
    /// accumulation is a HashMap of integer keys; the result is then
    /// ordered canonically by constraint *content* so piece order — and
    /// with it every report — is identical across processes regardless of
    /// interning order.
    pub fn compact(&mut self) {
        let mut by_guard: HashMap<Guard, Poly> = HashMap::new();
        for (g, p) in self.pieces.drain(..) {
            match by_guard.entry(g) {
                std::collections::hash_map::Entry::Occupied(mut e) => {
                    e.get_mut().add_assign(&p);
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(p);
                }
            }
        }
        let pool = guard::pool_read();
        let mut keyed: Vec<_> = by_guard
            .into_iter()
            .filter(|(_, p)| !p.is_zero())
            .map(|(g, p)| (g.sort_key(&pool), g, p))
            .collect();
        keyed.sort_by(|a, b| a.0.cmp(&b.0));
        self.pieces = keyed.into_iter().map(|(_, g, p)| (g, p)).collect();
    }

    /// Sum of another guarded sum into this one.
    pub fn add_assign(&mut self, other: &GuardedSum) {
        debug_assert_eq!(self.nparams, other.nparams);
        self.pieces.extend(other.pieces.iter().cloned());
    }

    /// Scale every piece by an integer factor.
    pub fn scale(&self, c: i128) -> GuardedSum {
        GuardedSum {
            nparams: self.nparams,
            pieces: self
                .pieces
                .iter()
                .map(|(g, p)| (g.clone(), p.scale(c)))
                .collect(),
        }
    }

    /// Evaluate at a concrete parameter point. O(#pieces), one shared
    /// pool view for every guard of the sum.
    pub fn eval(&self, params: &[i64]) -> i128 {
        let pool = guard::pool_read();
        let mut acc: i128 = 0;
        for (g, p) in &self.pieces {
            if g.holds_in(&pool, params) {
                acc += p.eval(params);
            }
        }
        acc
    }

    /// Disjoint chamber decomposition relative to a `context` guard (the
    /// global assumptions, e.g. `p_l ≥ 1`, `N_l ≥ 1`, array-size coupling).
    ///
    /// Splits the parameter space recursively on each atomic constraint and
    /// sums the polynomials of satisfied pieces per leaf chamber. Exact but
    /// worst-case exponential in the number of atoms; `max_chambers` caps
    /// the output (returns `None` if exceeded — callers fall back to the
    /// additive form, which is always exact for evaluation). Feasibility
    /// queries are memoized across the whole decomposition.
    pub fn disjointify(
        &self,
        context: &Guard,
        max_chambers: usize,
    ) -> Option<PiecewiseQPoly> {
        // Distinct atomic constraints over all guards, in canonical
        // (content) order so the printed case order is process-stable.
        let atoms: Vec<(u32, &'static Constraint)> = {
            let pool = guard::pool_read();
            let mut ids: Vec<u32> = self
                .pieces
                .iter()
                .flat_map(|(g, _)| g.ids().iter().copied())
                .collect();
            ids.sort_unstable();
            ids.dedup();
            let mut v: Vec<(u32, &'static Constraint)> =
                ids.into_iter().map(|id| (id, pool.get(id))).collect();
            v.sort_by(|a, b| a.1.cmp(b.1));
            v
        };
        // Chambers here always include `context` (the stack seeds with
        // it), so the memo context is trivial.
        let feas = SymbolicCtx::new(&Guard::always());
        let mut out: Vec<(Guard, Poly)> = Vec::new();
        // Worklist of (chamber, atom index, active piece indices).
        let all: Vec<usize> = (0..self.pieces.len()).collect();
        let mut stack: Vec<(Guard, usize, Vec<usize>)> =
            vec![(context.clone(), 0, all)];
        while let Some((chamber, ai, active)) = stack.pop() {
            if active.is_empty() {
                continue; // zero region: omitted (the final `otherwise 0`)
            }
            // Find the next atom that is *undecided* for some active piece.
            let mut next = None;
            for idx in ai..atoms.len() {
                let (aid, a) = atoms[idx];
                let relevant = active
                    .iter()
                    .any(|&pi| self.pieces[pi].0.contains_id(aid));
                if relevant {
                    // Is it already decided by the chamber?
                    let with_true = chamber.and((*a).clone());
                    let with_false = chamber.and(a.negated());
                    let t = feas.feasible(&with_true);
                    let f = feas.feasible(&with_false);
                    if t && f {
                        next = Some((idx, with_true, with_false));
                        break;
                    }
                    // decided: filter pieces that require the false branch
                    if t && !f {
                        continue; // always true here, nothing to split
                    }
                    if !t && f {
                        continue;
                    }
                    // both infeasible: chamber itself empty
                    next = None;
                    break;
                }
            }
            match next {
                Some((idx, with_true, with_false)) => {
                    let aid = atoms[idx].0;
                    // True branch: pieces keep; false branch: drop pieces
                    // whose guard contains the atom.
                    let keep_true = active.clone();
                    let keep_false: Vec<usize> = active
                        .iter()
                        .copied()
                        .filter(|&pi| !self.pieces[pi].0.contains_id(aid))
                        .collect();
                    stack.push((with_true, idx + 1, keep_true));
                    stack.push((with_false, idx + 1, keep_false));
                    if stack.len() + out.len() > max_chambers * 4 {
                        return None;
                    }
                }
                None => {
                    if !feas.feasible(&chamber) {
                        continue;
                    }
                    // Leaf: every remaining active piece whose guard is
                    // implied by the chamber contributes.
                    let mut acc = Poly::zero(self.nparams);
                    for &pi in &active {
                        let (g, p) = &self.pieces[pi];
                        // All atoms of g must be satisfied in this chamber:
                        // they are, unless the chamber makes one infeasible.
                        let members: Vec<(u32, &'static Constraint)> = {
                            let pool = guard::pool_read();
                            g.ids()
                                .iter()
                                .map(|&id| (id, pool.get(id)))
                                .collect()
                        };
                        let ok = members.iter().all(|&(id, c)| {
                            chamber.contains_id(id)
                                || !feas.feasible(&chamber.and(c.negated()))
                        });
                        if ok {
                            acc.add_assign(p);
                        }
                    }
                    if !acc.is_zero() {
                        out.push((chamber.simplified(context), acc));
                        if out.len() > max_chambers {
                            return None;
                        }
                    }
                }
            }
        }
        // Merge leaves with identical polynomials? Keep simple: group them.
        Some(PiecewiseQPoly { nparams: self.nparams, cases: out })
    }
}

/// A disjoint case expression: at most one case applies per parameter
/// point (within the decomposition context); value is 0 otherwise.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PiecewiseQPoly {
    nparams: usize,
    pub cases: Vec<(Guard, Poly)>,
}

impl PiecewiseQPoly {
    /// Evaluate (sums all matching cases; disjointness makes ≤1 match).
    pub fn eval(&self, params: &[i64]) -> i128 {
        self.cases
            .iter()
            .filter(|(g, _)| g.holds(params))
            .map(|(_, p)| p.eval(params))
            .sum()
    }

    /// Number of cases.
    pub fn len(&self) -> usize {
        self.cases.len()
    }

    /// True when there are no cases (identically zero).
    pub fn is_empty(&self) -> bool {
        self.cases.is_empty()
    }

    /// Pretty-print in the paper's Example-9 style.
    pub fn display<'a>(&'a self, space: &'a ParamSpace) -> PiecewiseDisplay<'a> {
        PiecewiseDisplay { pw: self, space }
    }
}

/// Formatting helper for [`PiecewiseQPoly`].
pub struct PiecewiseDisplay<'a> {
    pw: &'a PiecewiseQPoly,
    space: &'a ParamSpace,
}

impl fmt::Display for PiecewiseDisplay<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.pw.cases.is_empty() {
            return write!(f, "0");
        }
        writeln!(f, "{{")?;
        for (g, p) in &self.pw.cases {
            writeln!(
                f,
                "  {}  if {}",
                p.display(self.space),
                g.display(self.space)
            )?;
        }
        writeln!(f, "  0  otherwise")?;
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::expr::AffineExpr;

    fn sp() -> ParamSpace {
        ParamSpace::loop_nest(1) // N0 p0
    }
    fn n0(s: &ParamSpace) -> AffineExpr {
        AffineExpr::param(s.len(), 0)
    }
    fn p0(s: &ParamSpace) -> AffineExpr {
        AffineExpr::param(s.len(), 1)
    }
    fn cst(s: &ParamSpace, c: i64) -> AffineExpr {
        AffineExpr::constant(s.len(), c)
    }

    #[test]
    fn guarded_sum_eval_additive() {
        let s = sp();
        let mut gs = GuardedSum::zero(s.len());
        // piece 1: N0 (if N0 >= 5)
        gs.push(
            Guard::new(vec![Constraint::ge(&n0(&s), &cst(&s, 5))]),
            Poly::from_affine(&n0(&s)),
        );
        // piece 2: 2 (always)
        gs.push(Guard::always(), Poly::constant(s.len(), 2));
        assert_eq!(gs.eval(&[3, 0]), 2);
        assert_eq!(gs.eval(&[7, 0]), 9);
    }

    #[test]
    fn push_drops_trivial() {
        let s = sp();
        let mut gs = GuardedSum::zero(s.len());
        gs.push(Guard::always(), Poly::zero(s.len()));
        let false_g = Guard::new(vec![Constraint::ge0(cst(&s, -1))]);
        gs.push(false_g, Poly::constant(s.len(), 10));
        assert!(gs.pieces.is_empty());
    }

    #[test]
    fn compact_merges_equal_guards() {
        let s = sp();
        let g = Guard::new(vec![Constraint::ge(&n0(&s), &cst(&s, 1))]);
        let mut gs = GuardedSum::zero(s.len());
        gs.push(g.clone(), Poly::constant(s.len(), 3));
        gs.push(g.clone(), Poly::constant(s.len(), 4));
        gs.compact();
        assert_eq!(gs.pieces.len(), 1);
        assert_eq!(gs.eval(&[1, 0]), 7);
    }

    #[test]
    fn compact_removes_cancelled() {
        let s = sp();
        let g = Guard::always();
        let mut gs = GuardedSum::zero(s.len());
        gs.push(g.clone(), Poly::constant(s.len(), 3));
        gs.push(g.clone(), Poly::constant(s.len(), -3));
        gs.compact();
        assert!(gs.pieces.is_empty());
    }

    #[test]
    fn compact_orders_pieces_canonically() {
        // Piece order after compaction follows constraint content, not
        // interning order: building the same sum twice with the guards
        // first seen in opposite orders must yield identical piece lists.
        let s = sp();
        let ga = Guard::new(vec![Constraint::ge(&n0(&s), &cst(&s, 7))]);
        let gb = Guard::new(vec![Constraint::ge(&p0(&s), &cst(&s, 5))]);
        let mut one = GuardedSum::zero(s.len());
        one.push(ga.clone(), Poly::constant(s.len(), 1));
        one.push(gb.clone(), Poly::constant(s.len(), 2));
        one.compact();
        let mut two = GuardedSum::zero(s.len());
        two.push(gb, Poly::constant(s.len(), 2));
        two.push(ga, Poly::constant(s.len(), 1));
        two.compact();
        assert_eq!(one, two);
    }

    #[test]
    fn disjointify_matches_eval() {
        let s = sp();
        let ctx = Guard::new(vec![
            Constraint::ge(&n0(&s), &cst(&s, 1)),
            Constraint::ge(&p0(&s), &cst(&s, 1)),
        ]);
        let mut gs = GuardedSum::zero(s.len());
        // min(N0, 2p0)-style split: piece A if N0 <= 2p0, piece B if N0 > 2p0
        let two_p0 = &p0(&s) * 2;
        gs.push(
            Guard::new(vec![Constraint::le(&n0(&s), &two_p0)]),
            Poly::from_affine(&n0(&s)),
        );
        gs.push(
            Guard::new(vec![Constraint::gt(&n0(&s), &two_p0)]),
            Poly::from_affine(&two_p0),
        );
        // plus an unconditional +1
        gs.push(Guard::always(), Poly::constant(s.len(), 1));
        let pw = gs.disjointify(&ctx, 64).expect("small case count");
        for n in 1..10 {
            for p in 1..6 {
                assert_eq!(pw.eval(&[n, p]), gs.eval(&[n, p]), "N0={n} p0={p}");
            }
        }
        // Disjoint: every point in context satisfied by at most one case.
        for n in 1..10 {
            for p in 1..6 {
                let matches =
                    pw.cases.iter().filter(|(g, _)| g.holds(&[n, p])).count();
                assert!(matches <= 1, "N0={n} p0={p} matched {matches}");
            }
        }
    }

    #[test]
    fn scale_distributes() {
        let s = sp();
        let mut gs = GuardedSum::zero(s.len());
        gs.push(Guard::always(), Poly::from_affine(&n0(&s)));
        let doubled = gs.scale(2);
        assert_eq!(doubled.eval(&[5, 0]), 10);
    }
}
