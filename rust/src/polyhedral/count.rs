//! Concrete (fixed-parameter) lattice-point counting.
//!
//! Two counters, both exact:
//!
//! * [`count_concrete`] — the production path for fixed parameters:
//!   unfolds the (fixed, small) processor grid `k ∈ [0,t)` and multiplies
//!   per-dimension interval lengths. Complexity `O(Π t_ℓ · n · #constr)`,
//!   *independent of the loop bounds* `N` — this is why even the
//!   "concrete" analysis beats simulation asymptotically.
//! * [`count_bruteforce`] — full enumeration of `(j, k)` points. Test
//!   oracle only (cost proportional to the box volume).

use super::set::{k_grid, TiledSet};

/// Count `|{(j,k) ∈ set}|` at concrete parameter values.
///
/// `t` is the processor-array extent per dimension (the `k` box that is
/// unfolded); parameters are the concrete values of the [`super::expr::ParamSpace`]
/// the set was built against.
pub fn count_concrete(set: &TiledSet, t: &[i64], params: &[i64]) -> i128 {
    let mut total: i128 = 0;
    for k in k_grid(t) {
        let cell = set
            .substitute_k(&k)
            .expect("set outside the separable tiled class");
        // Pure-parameter conditions gate the whole cell.
        if !cell.param_conds.iter().all(|c| c.eval(params) >= 0) {
            continue;
        }
        let mut cell_count: i128 = 1;
        for db in &cell.dims {
            let lo = db
                .lowers
                .iter()
                .map(|e| e.eval(params))
                .max()
                .expect("dimension with no lower bound");
            let hi = db
                .uppers
                .iter()
                .map(|e| e.eval(params))
                .min()
                .expect("dimension with no upper bound");
            let len = (hi - lo + 1).max(0) as i128;
            cell_count *= len;
            if cell_count == 0 {
                break;
            }
        }
        total += cell_count;
    }
    total
}

/// Enumerate every `(j, k)` point (test oracle). The `j` box per dimension
/// is derived from the widest interval over all `k` cells.
pub fn count_bruteforce(set: &TiledSet, t: &[i64], params: &[i64]) -> i128 {
    let mut total = 0i128;
    for k in k_grid(t) {
        let cell = set
            .substitute_k(&k)
            .expect("set outside the separable tiled class");
        // Bounding box for j from the per-dim bounds (may be loose).
        let mut boxes = Vec::with_capacity(cell.dims.len());
        for db in &cell.dims {
            let lo = db
                .lowers
                .iter()
                .map(|e| e.eval(params))
                .max()
                .expect("dimension with no lower bound");
            let hi = db
                .uppers
                .iter()
                .map(|e| e.eval(params))
                .min()
                .expect("dimension with no upper bound");
            boxes.push((lo, hi));
        }
        // Enumerate and use full membership as the final word.
        let mut j = boxes.iter().map(|&(lo, _)| lo).collect::<Vec<_>>();
        if boxes.iter().any(|&(lo, hi)| lo > hi) {
            continue;
        }
        loop {
            if set.contains(&j, &k, params) {
                total += 1;
            }
            // increment odometer
            let mut d = 0;
            loop {
                if d == j.len() {
                    break;
                }
                j[d] += 1;
                if j[d] <= boxes[d].1 {
                    break;
                }
                j[d] = boxes[d].0;
                d += 1;
            }
            if d == j.len() {
                break;
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::expr::{AffineExpr, ParamSpace};

    /// The Example-2 base space: 0≤j<p, 0≤k<t(=2), 0≤j+pk<N over n=2.
    fn base_space(t: &[i64]) -> (ParamSpace, TiledSet) {
        let sp = ParamSpace::loop_nest(2);
        let np = sp.len();
        let mut set = TiledSet::universe(2, np);
        let p_idx = [sp.p_index(0), sp.p_index(1)];
        for l in 0..2 {
            set.add_tile_bounds(l, p_idx[l]);
            set.add_array_bounds(l, t[l]);
            let mut a = [0i64; 2];
            a[l] = 1;
            set.add_global_affine(&a, AffineExpr::zero(np), &p_idx);
            let mut an = [0i64; 2];
            an[l] = -1;
            set.add_global_affine(
                &an,
                AffineExpr::param(np, sp.n_index(l)).plus(-1),
                &p_idx,
            );
        }
        (sp, set)
    }

    #[test]
    fn full_iteration_space_count() {
        // Exact cover: N=4x5 tiles 2x3 on 2x2 array → all 20 iterations.
        let (_, set) = base_space(&[2, 2]);
        assert_eq!(count_concrete(&set, &[2, 2], &[4, 5, 2, 3]), 20);
        assert_eq!(count_bruteforce(&set, &[2, 2], &[4, 5, 2, 3]), 20);
    }

    #[test]
    fn ragged_cover_clips_to_n() {
        // N=5x5, tiles 3x3, 2x2 array: tiles overhang, count must be 25.
        let (_, set) = base_space(&[2, 2]);
        assert_eq!(count_concrete(&set, &[2, 2], &[5, 5, 3, 3]), 25);
        assert_eq!(count_bruteforce(&set, &[2, 2], &[5, 5, 3, 3]), 25);
    }

    #[test]
    fn undersized_tiling_counts_partial() {
        // Tiles too small to cover: 2x2 tiles on 2x2 array covers only
        // 4x4=16 of the 6x6=36 iterations.
        let (_, set) = base_space(&[2, 2]);
        assert_eq!(count_concrete(&set, &[2, 2], &[6, 6, 2, 2]), 16);
        assert_eq!(count_bruteforce(&set, &[2, 2], &[6, 6, 2, 2]), 16);
    }

    #[test]
    fn concrete_matches_bruteforce_randomized() {
        // Light-weight randomized agreement sweep (full property tests live
        // in rust/tests/).
        let (_, set) = base_space(&[2, 2]);
        let mut seed = 0x9e3779b97f4a7c15u64;
        for _ in 0..50 {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            let n0 = 1 + (seed >> 33) % 8;
            let n1 = 1 + (seed >> 45) % 8;
            let p0 = 1 + (seed >> 20) % 4;
            let p1 = 1 + (seed >> 10) % 4;
            let params = [n0 as i64, n1 as i64, p0 as i64, p1 as i64];
            assert_eq!(
                count_concrete(&set, &[2, 2], &params),
                count_bruteforce(&set, &[2, 2], &params),
                "params={params:?}"
            );
        }
    }
}
