//! Parametric affine expressions.
//!
//! An [`AffineExpr`] is a linear form `c0 + Σ_i c_i · P_i` over a fixed
//! [`ParamSpace`] (e.g. `N0, N1, p0, p1` for a 2-deep loop nest). These are
//! the atoms of everything symbolic in this crate: loop-bound constraints,
//! chamber guards, and the per-dimension interval bounds whose products form
//! the piecewise quasi-polynomial volumes of §IV-C of the paper.

use std::fmt;
use std::ops::{Add, Mul, Neg, Sub};

/// Names of the symbolic parameters an analysis is parametric in.
///
/// By convention, a loop nest of depth `n` uses `N0..N{n-1}` (loop bounds)
/// followed by `p0..p{n-1}` (tile sizes). The processor-array extents
/// `t0..t{n-1}` are *fixed integers* (the paper analyzes a given array size
/// and unfolds all `k` constraints over it, cf. footnote 1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpace {
    names: Vec<String>,
}

impl ParamSpace {
    /// Create a parameter space from a list of names. Names must be unique.
    pub fn new<S: Into<String>>(names: Vec<S>) -> Self {
        let names: Vec<String> = names.into_iter().map(Into::into).collect();
        for (i, a) in names.iter().enumerate() {
            for b in names.iter().skip(i + 1) {
                assert_ne!(a, b, "duplicate parameter name {a:?}");
            }
        }
        ParamSpace { names }
    }

    /// The conventional space for an `n`-deep loop nest: `N0..,p0..`.
    pub fn loop_nest(n: usize) -> Self {
        let mut names = Vec::with_capacity(2 * n);
        for i in 0..n {
            names.push(format!("N{i}"));
        }
        for i in 0..n {
            names.push(format!("p{i}"));
        }
        ParamSpace::new(names)
    }

    /// Number of parameters.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True when the space has no parameters.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Index of the parameter called `name`.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    /// Name of parameter `i`.
    pub fn name(&self, i: usize) -> &str {
        &self.names[i]
    }

    /// All names, in index order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Index of loop bound `N{dim}` in a [`ParamSpace::loop_nest`] space.
    pub fn n_index(&self, dim: usize) -> usize {
        self.index_of(&format!("N{dim}"))
            .unwrap_or_else(|| panic!("no parameter N{dim}"))
    }

    /// Index of tile size `p{dim}` in a [`ParamSpace::loop_nest`] space.
    pub fn p_index(&self, dim: usize) -> usize {
        self.index_of(&format!("p{dim}"))
            .unwrap_or_else(|| panic!("no parameter p{dim}"))
    }
}

/// `konst + Σ coeffs[i] · P_i` with integer coefficients.
///
/// The coefficient vector always has exactly `ParamSpace::len()` entries;
/// expressions from different spaces must not be mixed (checked by length
/// in debug builds).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AffineExpr {
    pub coeffs: Vec<i64>,
    pub konst: i64,
}

impl AffineExpr {
    /// The zero expression over a space with `nparams` parameters.
    pub fn zero(nparams: usize) -> Self {
        AffineExpr { coeffs: vec![0; nparams], konst: 0 }
    }

    /// A constant expression.
    pub fn constant(nparams: usize, c: i64) -> Self {
        AffineExpr { coeffs: vec![0; nparams], konst: c }
    }

    /// The expression `P_i` (a single parameter).
    pub fn param(nparams: usize, i: usize) -> Self {
        let mut coeffs = vec![0; nparams];
        coeffs[i] = 1;
        AffineExpr { coeffs, konst: 0 }
    }

    /// `coeff · P_i + konst`.
    pub fn param_scaled(nparams: usize, i: usize, coeff: i64, konst: i64) -> Self {
        let mut coeffs = vec![0; nparams];
        coeffs[i] = coeff;
        AffineExpr { coeffs, konst }
    }

    /// Number of parameters of the underlying space.
    pub fn nparams(&self) -> usize {
        self.coeffs.len()
    }

    /// True when all parameter coefficients are zero.
    pub fn is_const(&self) -> bool {
        self.coeffs.iter().all(|&c| c == 0)
    }

    /// The constant value, if [`Self::is_const`].
    pub fn as_const(&self) -> Option<i64> {
        if self.is_const() {
            Some(self.konst)
        } else {
            None
        }
    }

    /// Evaluate at a concrete parameter point.
    pub fn eval(&self, params: &[i64]) -> i64 {
        debug_assert_eq!(params.len(), self.coeffs.len());
        i64::try_from(self.eval_i128(params))
            .expect("affine evaluation overflow")
    }

    /// Evaluate in `i128` (cannot overflow for `i64` inputs: the sum of
    /// `n` products of two `i64`s stays far below `i128::MAX`).
    #[inline]
    fn eval_i128(&self, params: &[i64]) -> i128 {
        let mut acc = self.konst as i128;
        for (c, p) in self.coeffs.iter().zip(params) {
            acc += (*c as i128) * (*p as i128);
        }
        acc
    }

    /// Sign-only evaluation: `true` iff the form is ≥ 0 at `params`.
    /// Guard evaluation uses this — a huge-but-valid value must not panic
    /// the `i64` narrowing of [`Self::eval`].
    #[inline]
    pub fn nonneg_at(&self, params: &[i64]) -> bool {
        debug_assert_eq!(params.len(), self.coeffs.len());
        self.eval_i128(params) >= 0
    }

    /// Add a constant in place, returning self (builder style).
    pub fn plus(mut self, c: i64) -> Self {
        self.konst += c;
        self
    }

    /// Multiply all coefficients by `s`.
    pub fn scaled(mut self, s: i64) -> Self {
        for c in &mut self.coeffs {
            *c *= s;
        }
        self.konst *= s;
        self
    }

    /// Divide all coefficients by their (positive) gcd including the
    /// constant; used to normalize guard constraints. Returns the gcd.
    pub fn reduce_gcd(&mut self) -> i64 {
        let mut g = self.konst.unsigned_abs();
        for &c in &self.coeffs {
            g = gcd_u64(g, c.unsigned_abs());
        }
        if g > 1 {
            let g = g as i64;
            self.konst /= g;
            for c in &mut self.coeffs {
                *c /= g;
            }
            g
        } else {
            1
        }
    }

    /// Pretty-print against a parameter space.
    pub fn display<'a>(&'a self, space: &'a ParamSpace) -> AffineDisplay<'a> {
        AffineDisplay { expr: self, space }
    }
}

/// Greatest common divisor of two unsigned values.
pub fn gcd_u64(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Add for &AffineExpr {
    type Output = AffineExpr;
    fn add(self, rhs: &AffineExpr) -> AffineExpr {
        debug_assert_eq!(self.coeffs.len(), rhs.coeffs.len());
        AffineExpr {
            coeffs: self
                .coeffs
                .iter()
                .zip(&rhs.coeffs)
                .map(|(a, b)| a + b)
                .collect(),
            konst: self.konst + rhs.konst,
        }
    }
}

impl Sub for &AffineExpr {
    type Output = AffineExpr;
    fn sub(self, rhs: &AffineExpr) -> AffineExpr {
        debug_assert_eq!(self.coeffs.len(), rhs.coeffs.len());
        AffineExpr {
            coeffs: self
                .coeffs
                .iter()
                .zip(&rhs.coeffs)
                .map(|(a, b)| a - b)
                .collect(),
            konst: self.konst - rhs.konst,
        }
    }
}

impl Neg for &AffineExpr {
    type Output = AffineExpr;
    fn neg(self) -> AffineExpr {
        AffineExpr {
            coeffs: self.coeffs.iter().map(|c| -c).collect(),
            konst: -self.konst,
        }
    }
}

impl Mul<i64> for &AffineExpr {
    type Output = AffineExpr;
    fn mul(self, s: i64) -> AffineExpr {
        self.clone().scaled(s)
    }
}

/// Helper for `{}`-formatting an [`AffineExpr`] with parameter names.
pub struct AffineDisplay<'a> {
    expr: &'a AffineExpr,
    space: &'a ParamSpace,
}

impl fmt::Display for AffineDisplay<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut wrote = false;
        for (i, &c) in self.expr.coeffs.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let name = self.space.name(i);
            if wrote {
                write!(f, " {} ", if c < 0 { "-" } else { "+" })?;
            } else if c < 0 {
                write!(f, "-")?;
            }
            let a = c.unsigned_abs();
            if a == 1 {
                write!(f, "{name}")?;
            } else {
                write!(f, "{a}{name}")?;
            }
            wrote = true;
        }
        let k = self.expr.konst;
        if k != 0 || !wrote {
            if wrote {
                write!(f, " {} {}", if k < 0 { "-" } else { "+" }, k.abs())?;
            } else {
                write!(f, "{k}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space2() -> ParamSpace {
        ParamSpace::loop_nest(1) // N0, p0
    }

    #[test]
    fn loop_nest_space_layout() {
        let s = ParamSpace::loop_nest(2);
        assert_eq!(s.len(), 4);
        assert_eq!(s.name(0), "N0");
        assert_eq!(s.name(1), "N1");
        assert_eq!(s.name(2), "p0");
        assert_eq!(s.name(3), "p1");
        assert_eq!(s.n_index(1), 1);
        assert_eq!(s.p_index(0), 2);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_names_rejected() {
        ParamSpace::new(vec!["a", "a"]);
    }

    #[test]
    fn eval_basic() {
        let s = space2();
        // 2*N0 - 3*p0 + 7
        let e = &(&AffineExpr::param(s.len(), 0) * 2)
            - &AffineExpr::param_scaled(s.len(), 1, 3, -7);
        assert_eq!(e.eval(&[10, 4]), 2 * 10 - 3 * 4 + 7);
    }

    #[test]
    fn nonneg_at_never_narrows() {
        let s = space2();
        // A value far past i64 would panic eval's narrowing; the sign-only
        // path must stay exact and calm.
        let e = AffineExpr::param_scaled(s.len(), 0, i64::MAX, 0);
        assert!(e.nonneg_at(&[i64::MAX, 0]));
        assert!(!(-&e).nonneg_at(&[i64::MAX, 0]));
    }

    #[test]
    fn arithmetic_identities() {
        let s = space2();
        let a = AffineExpr::param_scaled(s.len(), 0, 5, 2);
        let b = AffineExpr::param_scaled(s.len(), 1, -1, 3);
        let sum = &a + &b;
        let diff = &sum - &b;
        assert_eq!(diff, a);
        let negneg = -&(-&a);
        assert_eq!(negneg, a);
    }

    #[test]
    fn const_detection() {
        let s = space2();
        assert!(AffineExpr::constant(s.len(), 5).is_const());
        assert_eq!(AffineExpr::constant(s.len(), 5).as_const(), Some(5));
        assert!(!AffineExpr::param(s.len(), 0).is_const());
        assert_eq!(AffineExpr::param(s.len(), 0).as_const(), None);
    }

    #[test]
    fn gcd_reduce() {
        let s = space2();
        let mut e = AffineExpr::param_scaled(s.len(), 0, 6, -9);
        assert_eq!(e.reduce_gcd(), 3);
        assert_eq!(e, AffineExpr::param_scaled(s.len(), 0, 2, -3));
        // gcd of zero expr leaves it untouched
        let mut z = AffineExpr::zero(s.len());
        assert_eq!(z.reduce_gcd(), 1);
        assert_eq!(z, AffineExpr::zero(s.len()));
    }

    #[test]
    fn display_forms() {
        let s = ParamSpace::loop_nest(2);
        let n = s.len();
        let e = AffineExpr::param_scaled(n, 0, 2, -4); // 2N0 - 4
        assert_eq!(format!("{}", e.display(&s)), "2N0 - 4");
        let e2 = -&AffineExpr::param(n, 3); // -p1
        assert_eq!(format!("{}", e2.display(&s)), "-p1");
        let z = AffineExpr::zero(n);
        assert_eq!(format!("{}", z.display(&s)), "0");
        let c = AffineExpr::constant(n, -3);
        assert_eq!(format!("{}", c.display(&s)), "-3");
    }
}
