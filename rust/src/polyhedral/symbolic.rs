//! Symbolic (parametric) lattice-point counting — the ISL/Barvinok
//! substitute (§IV-C of the paper, incl. the footnote-1 unfolding).
//!
//! For each fixed tile origin `k` in the (fixed-size) processor grid, the
//! tiled statement space collapses to separable per-dimension bounds
//! `max(L_ℓ) ≤ j_ℓ ≤ min(U_ℓ)` with every bound *affine in the parameters*
//! `(N, p)`. The count of a cell is `Π_ℓ max(0, min(U_ℓ) − max(L_ℓ) + 1)` —
//! resolved into a **piecewise polynomial** by recursively splitting the
//! parameter space:
//!
//! 1. `max`/`min` of affine bounds → tournament splits on sign conditions
//!    of pairwise differences;
//! 2. the outer clamp `max(0, len)` → split on `len ≥ 1`, dropping the
//!    empty branch;
//! 3. pure-parameter cell conditions → chamber constraints.
//!
//! Branches infeasible under the evaluation context (Fourier–Motzkin) are
//! pruned. The result is a [`GuardedSum`] — exact at every parameter point
//! of the context, property-tested against the enumeration oracle — which
//! can be disjointified into the paper's Example-9 case expressions.
//!
//! # Feasibility caching
//!
//! Guards repeat massively — across the unfolded `k` cells (bounds differ
//! only by constant shifts that normalize identically), across the
//! dimensions of one cell, across the statement variants of one analysis,
//! and across the design points of a DSE sweep that share a parameter
//! context. [`SymbolicCtx`] memoizes Fourier–Motzkin feasibility per
//! (interned) guard for one fixed context; [`FeasPool`] hands out one
//! shared [`SymbolicCtx`] per distinct context so a whole
//! `WorkloadAnalysis` — and, through `dse::AnalysisCache`, a whole sweep —
//! runs Fourier–Motzkin **once per distinct guard**.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::expr::AffineExpr;
use super::guard::{Constraint, Guard};
use super::piecewise::GuardedSum;
use super::poly::Poly;
use super::set::{k_grid, DimBounds, TiledSet};
use crate::cancel::CancelToken;

/// Panic payload raised by [`check_point_guard`] when the installed
/// guard's [`CancelToken`] has tripped. Callers that `catch_unwind`
/// an analysis (the DSE cache does) classify the abort by this
/// substring — it must stay stable.
pub const POINT_CANCELLED_PANIC: &str = "tcpa: point cancelled";

/// Panic payload raised by [`check_point_guard`] when the installed
/// guard's per-point timeout has elapsed. Stable, like
/// [`POINT_CANCELLED_PANIC`].
pub const POINT_TIMEOUT_PANIC: &str = "tcpa: point timeout";

/// Per-thread cooperative abort guard for one design-point analysis.
///
/// The DSE worker installs one via [`set_point_guard`] around each
/// `evaluate` call; the Fourier–Motzkin hot loops call
/// [`check_point_guard`] so a pathological chamber blow-up cannot
/// wedge a worker past its `--point-timeout` or keep it busy after
/// the sweep was cancelled. Aborting is done by panicking with a
/// stable payload ([`POINT_CANCELLED_PANIC`] /
/// [`POINT_TIMEOUT_PANIC`]) that the worker's `catch_unwind` layer
/// turns back into a classified outcome.
#[derive(Debug, Clone)]
pub struct PointGuard {
    cancel: CancelToken,
    timeout_at: Option<Instant>,
}

impl PointGuard {
    /// A guard observing `cancel`, with an optional per-point budget
    /// measured from now.
    pub fn new(cancel: CancelToken, timeout: Option<Duration>) -> Self {
        PointGuard {
            cancel,
            timeout_at: timeout.map(|t| Instant::now() + t),
        }
    }
}

thread_local! {
    static POINT_GUARD: RefCell<Option<PointGuard>> =
        const { RefCell::new(None) };
    static GUARD_TICK: Cell<u32> = const { Cell::new(0) };
}

/// Install (`Some`) or clear (`None`) the calling thread's point
/// guard. With no guard installed [`check_point_guard`] is a no-op,
/// so library users outside the DSE pool pay one thread-local read.
pub fn set_point_guard(guard: Option<PointGuard>) {
    GUARD_TICK.with(|t| t.set(0));
    POINT_GUARD.with(|g| *g.borrow_mut() = guard);
}

/// Cooperative abort point for the symbolic/Fourier–Motzkin loops.
///
/// Cheap by construction: every call does one flag-only
/// [`CancelToken::tripped`] load; the clock (deadline, SIGINT latch,
/// per-point timeout) is consulted on the first call after the guard
/// is installed and every 64th call thereafter, so even a count that
/// finishes in a handful of branches observes an expired timeout.
pub fn check_point_guard() {
    POINT_GUARD.with(|slot| {
        let g = slot.borrow();
        let Some(g) = g.as_ref() else { return };
        if g.cancel.tripped() {
            panic!("{POINT_CANCELLED_PANIC}");
        }
        let tick = GUARD_TICK.with(|t| {
            let v = t.get().wrapping_add(1);
            t.set(v);
            v
        });
        if tick % 64 != 1 {
            return;
        }
        if g.cancel.is_cancelled() {
            panic!("{POINT_CANCELLED_PANIC}");
        }
        if let Some(at) = g.timeout_at {
            if Instant::now() >= at {
                panic!("{POINT_TIMEOUT_PANIC}");
            }
        }
    });
}

/// Tunables for the symbolic counter.
#[derive(Debug, Clone)]
pub struct SymbolicOptions {
    /// Abort a single cell's resolution after this many branches
    /// (safety valve; practical statement spaces stay tiny).
    pub max_branches_per_cell: usize,
    /// Run [`GuardedSum::compact`] on the result.
    pub compact: bool,
}

impl Default for SymbolicOptions {
    fn default() -> Self {
        SymbolicOptions { max_branches_per_cell: 4096, compact: true }
    }
}

/// Hit/miss counters of a [`SymbolicCtx`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FeasStats {
    /// Queries answered from the memo table.
    pub hits: u64,
    /// Queries that ran Fourier–Motzkin.
    pub misses: u64,
}

/// Memoized feasibility of `guard ∧ context` for one fixed `context`.
///
/// Thread-safe and shareable (`Arc`): the memo table is a mutex-guarded
/// map keyed by the interned [`Guard`] — integer hashing, no expression
/// traffic. Fourier–Motzkin runs *outside* the lock; concurrent misses on
/// the same guard may duplicate a run, which is harmless (same result).
#[derive(Debug)]
pub struct SymbolicCtx {
    context: Guard,
    memo: Mutex<HashMap<Guard, bool>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SymbolicCtx {
    /// A fresh feasibility cache for `context`.
    pub fn new(context: &Guard) -> Self {
        SymbolicCtx {
            context: context.clone(),
            memo: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The context every query is conjoined with.
    pub fn context(&self) -> &Guard {
        &self.context
    }

    /// Memoized feasibility of `g ∧ context`.
    pub fn feasible(&self, g: &Guard) -> bool {
        check_point_guard();
        if g.has_false() {
            return false;
        }
        if let Some(&v) = self.memo.lock().unwrap().get(g) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let v = g.and_guard(&self.context).feasible();
        self.memo.lock().unwrap().insert(g.clone(), v);
        v
    }

    /// Current counters.
    pub fn stats(&self) -> FeasStats {
        FeasStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

/// A pool of [`SymbolicCtx`]s keyed by their context guard, so every
/// analysis (and every DSE point) with the same parameter context shares
/// one Fourier–Motzkin memo table.
#[derive(Debug, Default)]
pub struct FeasPool {
    ctxs: Mutex<HashMap<Guard, Arc<SymbolicCtx>>>,
}

impl FeasPool {
    /// An empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// The shared cache for `context` (created on first request).
    pub fn ctx_for(&self, context: &Guard) -> Arc<SymbolicCtx> {
        Arc::clone(
            self.ctxs
                .lock()
                .unwrap()
                .entry(context.clone())
                .or_insert_with(|| Arc::new(SymbolicCtx::new(context))),
        )
    }

    /// Number of distinct contexts seen.
    pub fn len(&self) -> usize {
        self.ctxs.lock().unwrap().len()
    }

    /// True when no context has been requested yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregate hit/miss counters over all contexts.
    pub fn stats(&self) -> FeasStats {
        let ctxs = self.ctxs.lock().unwrap();
        let mut out = FeasStats::default();
        for ctx in ctxs.values() {
            let s = ctx.stats();
            out.hits += s.hits;
            out.misses += s.misses;
        }
        out
    }
}

/// Count `|set|` symbolically over the parameters, valid within `context`
/// (the global assumptions, e.g. `N_ℓ ≥ 1 ∧ p_ℓ ≥ 1 ∧ …`), with a private
/// single-use feasibility cache. Analyses counting several statement
/// spaces under one context should use [`count_symbolic_in`] with a shared
/// [`SymbolicCtx`] instead.
pub fn count_symbolic(
    set: &TiledSet,
    t: &[i64],
    context: &Guard,
    opts: &SymbolicOptions,
) -> GuardedSum {
    count_symbolic_in(set, t, &SymbolicCtx::new(context), opts)
}

/// As [`count_symbolic`] against a caller-shared feasibility cache.
pub fn count_symbolic_in(
    set: &TiledSet,
    t: &[i64],
    ctx: &SymbolicCtx,
    opts: &SymbolicOptions,
) -> GuardedSum {
    let mut out = GuardedSum::zero(set.nparams);
    for k in k_grid(t) {
        let cell = set
            .substitute_k(&k)
            .expect("set outside the separable tiled class");
        // Cell-level parameter conditions.
        let mut cell_guard = Guard::always();
        let mut dead = false;
        for cond in &cell.param_conds {
            let c = Constraint::ge0(cond.clone());
            if c.as_const() == Some(false) {
                dead = true;
                break;
            }
            cell_guard = cell_guard.and(c);
        }
        if dead || !ctx.feasible(&cell_guard) {
            continue;
        }
        resolve_dims(
            &cell.dims,
            0,
            cell_guard,
            Poly::constant(set.nparams, 1),
            ctx,
            opts,
            &mut out,
            &mut 0usize,
        );
    }
    if opts.compact {
        out.compact();
    }
    out
}

/// Recursively resolve dimension bounds into guarded polynomial pieces.
#[allow(clippy::too_many_arguments)]
fn resolve_dims(
    dims: &[DimBounds],
    d: usize,
    guard: Guard,
    acc: Poly,
    ctx: &SymbolicCtx,
    opts: &SymbolicOptions,
    out: &mut GuardedSum,
    branches: &mut usize,
) {
    check_point_guard();
    *branches += 1;
    assert!(
        *branches <= opts.max_branches_per_cell,
        "symbolic counter exceeded {} branches on one cell",
        opts.max_branches_per_cell
    );
    if d == dims.len() {
        out.push(guard, acc);
        return;
    }
    let db = &dims[d];
    assert!(
        !db.lowers.is_empty() && !db.uppers.is_empty(),
        "dimension {d} lacks a finite bound"
    );
    resolve_extremum(
        &db.lowers, guard, ctx, opts, branches, true,
        &mut |lo: AffineExpr, g: Guard, br: &mut usize| {
            resolve_extremum(
                &db.uppers, g, ctx, opts, br, false,
                &mut |hi: AffineExpr, g2: Guard, br2: &mut usize| {
                    // len = hi - lo + 1; split on len >= 1 i.e. hi - lo >= 0.
                    let len = (&hi - &lo).plus(1);
                    let nonempty = Constraint::ge0((&hi - &lo).clone());
                    match nonempty.as_const() {
                        Some(false) => (), // certainly empty
                        Some(true) => {
                            let g3 = g2.clone();
                            let acc2 = acc.mul(&Poly::from_affine(&len));
                            resolve_dims(
                                dims, d + 1, g3, acc2, ctx, opts, out, br2,
                            );
                        }
                        None => {
                            // non-empty branch
                            let g_yes = g2.and(nonempty.clone());
                            if ctx.feasible(&g_yes) {
                                let acc2 = acc.mul(&Poly::from_affine(&len));
                                resolve_dims(
                                    dims, d + 1, g_yes, acc2, ctx, opts,
                                    out, br2,
                                );
                            }
                            // empty branch contributes 0: dropped.
                        }
                    }
                },
            );
        },
    );
}

/// Shared tournament resolving `max(bounds)` (`want_max`) or `min(bounds)`:
/// repeatedly compare the current champion `c` with the next contender `x`,
/// splitting the chamber on `c ≥ x` (max) or `c ≤ x` (min). Syntactically-
/// equal bounds and context-decided comparisons do not split.
fn resolve_extremum(
    bounds: &[AffineExpr],
    guard: Guard,
    ctx: &SymbolicCtx,
    opts: &SymbolicOptions,
    branches: &mut usize,
    want_max: bool,
    f: &mut dyn FnMut(AffineExpr, Guard, &mut usize),
) {
    // Dedup identical bounds first.
    let mut uniq: Vec<AffineExpr> = Vec::with_capacity(bounds.len());
    for b in bounds {
        if !uniq.contains(b) {
            uniq.push(b.clone());
        }
    }
    struct Frame {
        champion: AffineExpr,
        next: usize,
        guard: Guard,
    }
    let mut stack = vec![Frame { champion: uniq[0].clone(), next: 1, guard }];
    while let Some(Frame { champion, next, guard }) = stack.pop() {
        check_point_guard();
        *branches += 1;
        assert!(
            *branches <= opts.max_branches_per_cell,
            "extremum resolution exceeded branch budget"
        );
        if next == uniq.len() {
            f(champion, guard, branches);
            continue;
        }
        let x = &uniq[next];
        // champion_wins: champion >= x (max) / champion <= x (min)
        let champion_wins = if want_max {
            Constraint::ge(&champion, x)
        } else {
            Constraint::le(&champion, x)
        };
        match champion_wins.as_const() {
            Some(true) => {
                stack.push(Frame { champion, next: next + 1, guard });
            }
            Some(false) => {
                stack.push(Frame { champion: x.clone(), next: next + 1, guard });
            }
            None => {
                let g_yes = guard.and(champion_wins.clone());
                let g_no = guard.and(champion_wins.negated());
                let yes_ok = ctx.feasible(&g_yes);
                let no_ok = ctx.feasible(&g_no);
                match (yes_ok, no_ok) {
                    (true, true) => {
                        stack.push(Frame {
                            champion: champion.clone(),
                            next: next + 1,
                            guard: g_yes,
                        });
                        stack.push(Frame {
                            champion: x.clone(),
                            next: next + 1,
                            guard: g_no,
                        });
                    }
                    (true, false) => stack.push(Frame {
                        champion,
                        next: next + 1,
                        guard, // decision implied: no new constraint needed
                    }),
                    (false, true) => stack.push(Frame {
                        champion: x.clone(),
                        next: next + 1,
                        guard,
                    }),
                    (false, false) => {} // dead chamber
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::count::{count_bruteforce, count_concrete};
    use crate::polyhedral::expr::{AffineExpr, ParamSpace};
    use crate::polyhedral::set::TiledSet;

    /// Standard evaluation context: N_l >= 1, 1 <= p_l <= N_l.
    fn context(sp: &ParamSpace, n: usize) -> Guard {
        let np = sp.len();
        let one = AffineExpr::constant(np, 1);
        let mut cs = Vec::new();
        for l in 0..n {
            let nl = AffineExpr::param(np, sp.n_index(l));
            let pl = AffineExpr::param(np, sp.p_index(l));
            cs.push(Constraint::ge(&nl, &one));
            cs.push(Constraint::ge(&pl, &one));
            cs.push(Constraint::le(&pl, &nl));
        }
        Guard::new(cs)
    }

    fn base_space(t: &[i64]) -> (ParamSpace, TiledSet) {
        let sp = ParamSpace::loop_nest(2);
        let np = sp.len();
        let mut set = TiledSet::universe(2, np);
        let p_idx = [sp.p_index(0), sp.p_index(1)];
        for l in 0..2 {
            set.add_tile_bounds(l, p_idx[l]);
            set.add_array_bounds(l, t[l]);
            let mut a = [0i64; 2];
            a[l] = 1;
            set.add_global_affine(&a, AffineExpr::zero(np), &p_idx);
            let mut an = [0i64; 2];
            an[l] = -1;
            set.add_global_affine(
                &an,
                AffineExpr::param(np, sp.n_index(l)).plus(-1),
                &p_idx,
            );
        }
        (sp, set)
    }

    #[test]
    fn symbolic_matches_concrete_on_base_space() {
        let (sp, set) = base_space(&[2, 2]);
        let ctx = context(&sp, 2);
        let sym = count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        for n0 in 1..8 {
            for n1 in 1..8 {
                for p0 in 1..=n0 {
                    for p1 in 1..=n1 {
                        let params = [n0, n1, p0, p1];
                        assert_eq!(
                            sym.eval(&params),
                            count_concrete(&set, &[2, 2], &params),
                            "params={params:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn shared_ctx_counts_identically_and_caches_across_calls() {
        // One SymbolicCtx across two statement spaces: identical results
        // to private caches, with cross-call memo hits.
        let (sp, set) = base_space(&[2, 2]);
        let (_, mut set2) = base_space(&[2, 2]);
        let np = sp.len();
        set2.add_global_affine(
            &[0, 1],
            AffineExpr::constant(np, -1),
            &[sp.p_index(0), sp.p_index(1)],
        );
        let ctx_guard = context(&sp, 2);
        let shared = SymbolicCtx::new(&ctx_guard);
        let opts = SymbolicOptions::default();
        let a1 = count_symbolic_in(&set, &[2, 2], &shared, &opts);
        let first = shared.stats();
        let b1 = count_symbolic_in(&set2, &[2, 2], &shared, &opts);
        let second = shared.stats();
        assert_eq!(a1, count_symbolic(&set, &[2, 2], &ctx_guard, &opts));
        assert_eq!(b1, count_symbolic(&set2, &[2, 2], &ctx_guard, &opts));
        // The second space re-asks many of the first space's guards.
        assert!(
            second.hits > first.hits,
            "expected cross-call hits: {first:?} → {second:?}"
        );
    }

    #[test]
    fn feas_pool_shares_ctx_per_context() {
        let (sp, _) = base_space(&[2, 2]);
        let g = context(&sp, 2);
        let pool = FeasPool::new();
        assert!(pool.is_empty());
        let a = pool.ctx_for(&g);
        let b = pool.ctx_for(&g);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(pool.len(), 1);
        let other = Guard::always();
        let c = pool.ctx_for(&other);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(pool.len(), 2);
    }

    #[test]
    fn symbolic_is_polynomial_in_exact_cover_chamber() {
        // With N = t*p exactly, the count must equal N0*N1 — check the
        // symbolic value over a sweep where p = N/2.
        let (sp, set) = base_space(&[2, 2]);
        let ctx = context(&sp, 2);
        let sym = count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        for h in 1..10 {
            let params = [2 * h, 2 * h, h, h];
            assert_eq!(sym.eval(&params), (4 * h * h) as i128);
        }
    }

    #[test]
    fn symbolic_example9_s7_star_1() {
        // Example 9: statement S7*1 on a 2x2 array.
        // Space: base ∧ (j1 + p1 k1 >= 1) ∧ (1 <= j1 <= p1 - 1 + 1 shifted):
        //   paper writes 0 <= j1 - 1 < p1 i.e. j1 >= 1 ∧ j1 <= p1.
        // Expected counts: e.g. N=(4,5), p=(2,3) → 12.
        let (sp, mut set) = base_space(&[2, 2]);
        let np = sp.len();
        let p_idx = [sp.p_index(0), sp.p_index(1)];
        // i1 >= 1  (condition i1 > 0)
        set.add_global_affine(&[0, 1], AffineExpr::constant(np, -1), &p_idx);
        // j1 - 1 in [0, p1-1]
        set.add_shifted_tile_membership(1, AffineExpr::constant(np, 1), p_idx[1]);
        let ctx = context(&sp, 2);
        let sym = count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        assert_eq!(sym.eval(&[4, 5, 2, 3]), 12, "paper Example 9: Vol(S7*1)=12");
        // And the paper's first chamber: 0<p0 ∧ 2p0<N0 ∧ p1>=2 ∧ 2p1<N1
        // → 4 p0 (p1 - 1). Try p0=2,N0=8,p1=3,N1=10: 4*2*2 = 16.
        assert_eq!(sym.eval(&[8, 10, 2, 3]), 16);
        // Second chamber: 2p0>=N0 → 2 N0 (p1-1): N0=3,p0=2,N1=10,p1=3 → 12.
        assert_eq!(sym.eval(&[3, 10, 2, 3]), 12);
        // Agreement with both oracles over a sweep.
        for n0 in 1..7 {
            for n1 in 1..7 {
                for p0 in 1..=n0 {
                    for p1 in 1..=n1 {
                        let params = [n0, n1, p0, p1];
                        let c = count_concrete(&set, &[2, 2], &params);
                        assert_eq!(sym.eval(&params), c, "params={params:?}");
                        assert_eq!(
                            count_bruteforce(&set, &[2, 2], &params),
                            c,
                            "params={params:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn symbolic_example9_s7_star_2() {
        // S7*2: inter-tile case. Space: base ∧ i1 >= 1 ∧
        //   j1 - (1 - p1) ∈ [0, p1-1]  ∧ k1 shifted by -1 in bounds:
        // the γ=(0,-1) variant reads from tile k1-1, valid when k1-1 >= 0,
        // i.e. k1 >= 1. Paper: Vol = 4 at N=(4,5), p=(2,3).
        let (sp, mut set) = base_space(&[2, 2]);
        let np = sp.len();
        let p_idx = [sp.p_index(0), sp.p_index(1)];
        set.add_global_affine(&[0, 1], AffineExpr::constant(np, -1), &p_idx);
        // j1 - (1 - p1) ∈ [0, p1 - 1]: off = 1 - p1 (affine).
        let off = (-&AffineExpr::param(np, p_idx[1])).plus(1);
        set.add_shifted_tile_membership(1, off, p_idx[1]);
        // k1 >= 1 (source tile exists)
        let mut c = crate::polyhedral::set::SetConstraint::zero(4, np);
        c.var_coeffs[set.kvar(1)] = AffineExpr::constant(np, 1);
        c.konst = AffineExpr::constant(np, -1);
        set.add(c);
        let ctx = context(&sp, 2);
        let sym = count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        assert_eq!(sym.eval(&[4, 5, 2, 3]), 4, "paper Example 9: Vol(S7*2)=4");
        // Paper chamber: 0 < p0 < N0/2 → 2 p0; p0 >= N0/2 → N0.
        assert_eq!(sym.eval(&[8, 10, 2, 3]), 4); // 2*p0 = 4
        assert_eq!(sym.eval(&[3, 10, 2, 3]), 3); // N0 = 3
        for n0 in 1..7 {
            for n1 in 1..7 {
                for p0 in 1..=n0 {
                    for p1 in 1..=n1 {
                        let params = [n0, n1, p0, p1];
                        assert_eq!(
                            sym.eval(&params),
                            count_concrete(&set, &[2, 2], &params),
                            "params={params:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn disjointified_form_matches() {
        let (sp, set) = base_space(&[2, 2]);
        let ctx = context(&sp, 2);
        let sym = count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        let pw = sym
            .disjointify(&ctx, 256)
            .expect("base space should disjointify");
        assert!(!pw.is_empty());
        for n0 in (1..9).step_by(2) {
            for n1 in (1..9).step_by(3) {
                for p0 in 1..=n0 {
                    for p1 in 1..=n1 {
                        let params = [n0, n1, p0, p1];
                        assert_eq!(
                            pw.eval(&params),
                            sym.eval(&params),
                            "params={params:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn point_guard_aborts_cancelled_counts_and_clears() {
        let (sp, set) = base_space(&[2, 2]);
        let ctx = context(&sp, 2);
        // A pre-cancelled guard aborts the count with the stable
        // payload the DSE worker classifies on.
        let token = CancelToken::new();
        token.cancel();
        set_point_guard(Some(PointGuard::new(token.clone(), None)));
        let err = std::panic::catch_unwind(|| {
            count_symbolic(&set, &[2, 2], &ctx, &Default::default())
        })
        .expect_err("cancelled count must abort");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| {
                err.downcast_ref::<&str>().map(|s| s.to_string())
            })
            .unwrap_or_default();
        assert!(
            msg.contains(POINT_CANCELLED_PANIC),
            "unexpected payload: {msg}"
        );
        // Clearing the guard restores normal operation on the same
        // thread even though the token stays tripped.
        set_point_guard(None);
        let sym =
            count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        assert_eq!(sym.eval(&[4, 4, 2, 2]), {
            count_concrete(&set, &[2, 2], &[4, 4, 2, 2])
        });
        // An untripped guard with no timeout never fires.
        set_point_guard(Some(PointGuard::new(
            CancelToken::new(),
            None,
        )));
        let again =
            count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        set_point_guard(None);
        assert_eq!(again.eval(&[4, 4, 2, 2]), sym.eval(&[4, 4, 2, 2]));
    }

    #[test]
    fn point_timeout_uses_the_amortized_clock_path() {
        let (sp, set) = base_space(&[2, 2]);
        let ctx = context(&sp, 2);
        // An already-expired timeout fires on the every-64th-call slow
        // path; the counting loops make far more than 64 guard calls.
        set_point_guard(Some(PointGuard::new(
            CancelToken::new(),
            Some(std::time::Duration::ZERO),
        )));
        let err = std::panic::catch_unwind(|| {
            count_symbolic(&set, &[2, 2], &ctx, &Default::default())
        });
        set_point_guard(None);
        let err = err.expect_err("expired timeout must abort");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| {
                err.downcast_ref::<&str>().map(|s| s.to_string())
            })
            .unwrap_or_default();
        assert!(
            msg.contains(POINT_TIMEOUT_PANIC),
            "unexpected payload: {msg}"
        );
    }
}
