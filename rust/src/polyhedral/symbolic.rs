//! Symbolic (parametric) lattice-point counting — the ISL/Barvinok
//! substitute (§IV-C of the paper, incl. the footnote-1 unfolding).
//!
//! For each fixed tile origin `k` in the (fixed-size) processor grid, the
//! tiled statement space collapses to separable per-dimension bounds
//! `max(L_ℓ) ≤ j_ℓ ≤ min(U_ℓ)` with every bound *affine in the parameters*
//! `(N, p)`. The count of a cell is `Π_ℓ max(0, min(U_ℓ) − max(L_ℓ) + 1)` —
//! resolved into a **piecewise polynomial** by recursively splitting the
//! parameter space:
//!
//! 1. `max`/`min` of affine bounds → tournament splits on sign conditions
//!    of pairwise differences;
//! 2. the outer clamp `max(0, len)` → split on `len ≥ 1`, dropping the
//!    empty branch;
//! 3. pure-parameter cell conditions → chamber constraints.
//!
//! Branches infeasible under the evaluation context (Fourier–Motzkin) are
//! pruned. The result is a [`GuardedSum`] — exact at every parameter point
//! of the context, property-tested against the enumeration oracle — which
//! can be disjointified into the paper's Example-9 case expressions.

use std::cell::RefCell;
use std::collections::HashMap;

use super::expr::AffineExpr;
use super::guard::{Constraint, Guard};
use super::piecewise::GuardedSum;
use super::poly::Poly;
use super::set::{k_grid, DimBounds, TiledSet};

/// Tunables for the symbolic counter.
#[derive(Debug, Clone)]
pub struct SymbolicOptions {
    /// Abort a single cell's resolution after this many branches
    /// (safety valve; practical statement spaces stay tiny).
    pub max_branches_per_cell: usize,
    /// Run [`GuardedSum::compact`] on the result.
    pub compact: bool,
}

impl Default for SymbolicOptions {
    fn default() -> Self {
        SymbolicOptions { max_branches_per_cell: 4096, compact: true }
    }
}


/// Memoized feasibility of `guard ∧ context`. Guards repeat massively
/// across the unfolded `k` cells (the bounds differ only by constant
/// shifts that normalize identically), so caching Fourier–Motzkin results
/// cuts the one-time analysis cost dramatically (§Perf).
struct FeasCache<'a> {
    context: &'a Guard,
    map: HashMap<Guard, bool>,
}

impl<'a> FeasCache<'a> {
    fn new(context: &'a Guard) -> Self {
        FeasCache { context, map: HashMap::new() }
    }

    fn feasible(&mut self, g: &Guard) -> bool {
        if g.has_false() {
            return false;
        }
        if let Some(&v) = self.map.get(g) {
            return v;
        }
        let v = g.and_guard(self.context).feasible();
        self.map.insert(g.clone(), v);
        v
    }
}

/// Count `|set|` symbolically over the parameters, valid within `context`
/// (the global assumptions, e.g. `N_ℓ ≥ 1 ∧ p_ℓ ≥ 1 ∧ …`).
pub fn count_symbolic(
    set: &TiledSet,
    t: &[i64],
    context: &Guard,
    opts: &SymbolicOptions,
) -> GuardedSum {
    let mut out = GuardedSum::zero(set.nparams);
    let cache = RefCell::new(FeasCache::new(context));
    for k in k_grid(t) {
        let cell = set
            .substitute_k(&k)
            .expect("set outside the separable tiled class");
        // Cell-level parameter conditions.
        let mut cell_guard = Guard::always();
        let mut dead = false;
        for cond in &cell.param_conds {
            let c = Constraint::ge0(cond.clone());
            if c.as_const() == Some(false) {
                dead = true;
                break;
            }
            cell_guard = cell_guard.and(c);
        }
        if dead || !cache.borrow_mut().feasible(&cell_guard) {
            continue;
        }
        resolve_dims(
            &cell.dims,
            0,
            cell_guard,
            Poly::constant(set.nparams, 1),
            &cache,
            opts,
            &mut out,
            &mut 0usize,
        );
    }
    if opts.compact {
        out.compact();
    }
    out
}

/// Recursively resolve dimension bounds into guarded polynomial pieces.
#[allow(clippy::too_many_arguments)]
fn resolve_dims(
    dims: &[DimBounds],
    d: usize,
    guard: Guard,
    acc: Poly,
    cache: &RefCell<FeasCache<'_>>,
    opts: &SymbolicOptions,
    out: &mut GuardedSum,
    branches: &mut usize,
) {
    *branches += 1;
    assert!(
        *branches <= opts.max_branches_per_cell,
        "symbolic counter exceeded {} branches on one cell",
        opts.max_branches_per_cell
    );
    if d == dims.len() {
        out.push(guard, acc);
        return;
    }
    let db = &dims[d];
    assert!(
        !db.lowers.is_empty() && !db.uppers.is_empty(),
        "dimension {d} lacks a finite bound"
    );
    resolve_max(
        &db.lowers, 0, guard, cache, opts, branches,
        &mut |lo: AffineExpr, g: Guard, br: &mut usize| {
            resolve_min(
                &db.uppers, 0, g, cache, opts, br,
                &mut |hi: AffineExpr, g2: Guard, br2: &mut usize| {
                    // len = hi - lo + 1; split on len >= 1 i.e. hi - lo >= 0.
                    let len = (&hi - &lo).plus(1);
                    let nonempty = Constraint::ge0((&hi - &lo).clone());
                    match nonempty.as_const() {
                        Some(false) => return, // certainly empty
                        Some(true) => {
                            let g3 = g2.clone();
                            let acc2 = acc.mul(&Poly::from_affine(&len));
                            resolve_dims(
                                dims, d + 1, g3, acc2, cache, opts, out, br2,
                            );
                        }
                        None => {
                            // non-empty branch
                            let g_yes = g2.and(nonempty.clone());
                            if cache.borrow_mut().feasible(&g_yes) {
                                let acc2 = acc.mul(&Poly::from_affine(&len));
                                resolve_dims(
                                    dims, d + 1, g_yes, acc2, cache, opts,
                                    out, br2,
                                );
                            }
                            // empty branch contributes 0: dropped.
                        }
                    }
                },
            );
        },
    );
}

/// Tournament-resolve `max(bounds[i..])` into (winner, guard) pairs.
fn resolve_max(
    bounds: &[AffineExpr],
    _start: usize,
    guard: Guard,
    cache: &RefCell<FeasCache<'_>>,
    opts: &SymbolicOptions,
    branches: &mut usize,
    f: &mut dyn FnMut(AffineExpr, Guard, &mut usize),
) {
    resolve_extremum(bounds, guard, cache, opts, branches, true, f)
}

/// Tournament-resolve `min(bounds[i..])`.
fn resolve_min(
    bounds: &[AffineExpr],
    _start: usize,
    guard: Guard,
    cache: &RefCell<FeasCache<'_>>,
    opts: &SymbolicOptions,
    branches: &mut usize,
    f: &mut dyn FnMut(AffineExpr, Guard, &mut usize),
) {
    resolve_extremum(bounds, guard, cache, opts, branches, false, f)
}

/// Shared tournament: repeatedly compare the current champion `c` with the
/// next contender `x`, splitting the chamber on `c ≥ x` (max) or `c ≤ x`
/// (min). Syntactically-equal bounds and context-decided comparisons do
/// not split.
fn resolve_extremum(
    bounds: &[AffineExpr],
    guard: Guard,
    cache: &RefCell<FeasCache<'_>>,
    opts: &SymbolicOptions,
    branches: &mut usize,
    want_max: bool,
    f: &mut dyn FnMut(AffineExpr, Guard, &mut usize),
) {
    // Dedup identical bounds first.
    let mut uniq: Vec<AffineExpr> = Vec::with_capacity(bounds.len());
    for b in bounds {
        if !uniq.contains(b) {
            uniq.push(b.clone());
        }
    }
    struct Frame {
        champion: AffineExpr,
        next: usize,
        guard: Guard,
    }
    let mut stack = vec![Frame { champion: uniq[0].clone(), next: 1, guard }];
    while let Some(Frame { champion, next, guard }) = stack.pop() {
        *branches += 1;
        assert!(
            *branches <= opts.max_branches_per_cell,
            "extremum resolution exceeded branch budget"
        );
        if next == uniq.len() {
            f(champion, guard, branches);
            continue;
        }
        let x = &uniq[next];
        // champion_wins: champion >= x (max) / champion <= x (min)
        let champion_wins = if want_max {
            Constraint::ge(&champion, x)
        } else {
            Constraint::le(&champion, x)
        };
        match champion_wins.as_const() {
            Some(true) => {
                stack.push(Frame { champion, next: next + 1, guard });
            }
            Some(false) => {
                stack.push(Frame { champion: x.clone(), next: next + 1, guard });
            }
            None => {
                let g_yes = guard.and(champion_wins.clone());
                let g_no = guard.and(champion_wins.negated());
                let yes_ok = cache.borrow_mut().feasible(&g_yes);
                let no_ok = cache.borrow_mut().feasible(&g_no);
                match (yes_ok, no_ok) {
                    (true, true) => {
                        stack.push(Frame {
                            champion: champion.clone(),
                            next: next + 1,
                            guard: g_yes,
                        });
                        stack.push(Frame {
                            champion: x.clone(),
                            next: next + 1,
                            guard: g_no,
                        });
                    }
                    (true, false) => stack.push(Frame {
                        champion,
                        next: next + 1,
                        guard, // decision implied: no new constraint needed
                    }),
                    (false, true) => stack.push(Frame {
                        champion: x.clone(),
                        next: next + 1,
                        guard,
                    }),
                    (false, false) => {} // dead chamber
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::count::{count_bruteforce, count_concrete};
    use crate::polyhedral::expr::{AffineExpr, ParamSpace};
    use crate::polyhedral::set::TiledSet;

    /// Standard evaluation context: N_l >= 1, 1 <= p_l <= N_l.
    fn context(sp: &ParamSpace, n: usize) -> Guard {
        let np = sp.len();
        let one = AffineExpr::constant(np, 1);
        let mut cs = Vec::new();
        for l in 0..n {
            let nl = AffineExpr::param(np, sp.n_index(l));
            let pl = AffineExpr::param(np, sp.p_index(l));
            cs.push(Constraint::ge(&nl, &one));
            cs.push(Constraint::ge(&pl, &one));
            cs.push(Constraint::le(&pl, &nl));
        }
        Guard::new(cs)
    }

    fn base_space(t: &[i64]) -> (ParamSpace, TiledSet) {
        let sp = ParamSpace::loop_nest(2);
        let np = sp.len();
        let mut set = TiledSet::universe(2, np);
        let p_idx = [sp.p_index(0), sp.p_index(1)];
        for l in 0..2 {
            set.add_tile_bounds(l, p_idx[l]);
            set.add_array_bounds(l, t[l]);
            let mut a = [0i64; 2];
            a[l] = 1;
            set.add_global_affine(&a, AffineExpr::zero(np), &p_idx);
            let mut an = [0i64; 2];
            an[l] = -1;
            set.add_global_affine(
                &an,
                AffineExpr::param(np, sp.n_index(l)).plus(-1),
                &p_idx,
            );
        }
        (sp, set)
    }

    #[test]
    fn symbolic_matches_concrete_on_base_space() {
        let (sp, set) = base_space(&[2, 2]);
        let ctx = context(&sp, 2);
        let sym = count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        for n0 in 1..8 {
            for n1 in 1..8 {
                for p0 in 1..=n0 {
                    for p1 in 1..=n1 {
                        let params = [n0, n1, p0, p1];
                        assert_eq!(
                            sym.eval(&params),
                            count_concrete(&set, &[2, 2], &params),
                            "params={params:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn symbolic_is_polynomial_in_exact_cover_chamber() {
        // With N = t*p exactly, the count must equal N0*N1 — check the
        // symbolic value over a sweep where p = N/2.
        let (sp, set) = base_space(&[2, 2]);
        let ctx = context(&sp, 2);
        let sym = count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        for h in 1..10 {
            let params = [2 * h, 2 * h, h, h];
            assert_eq!(sym.eval(&params), (4 * h * h) as i128);
        }
    }

    #[test]
    fn symbolic_example9_s7_star_1() {
        // Example 9: statement S7*1 on a 2x2 array.
        // Space: base ∧ (j1 + p1 k1 >= 1) ∧ (1 <= j1 <= p1 - 1 + 1 shifted):
        //   paper writes 0 <= j1 - 1 < p1 i.e. j1 >= 1 ∧ j1 <= p1.
        // Expected counts: e.g. N=(4,5), p=(2,3) → 12.
        let (sp, mut set) = base_space(&[2, 2]);
        let np = sp.len();
        let p_idx = [sp.p_index(0), sp.p_index(1)];
        // i1 >= 1  (condition i1 > 0)
        set.add_global_affine(&[0, 1], AffineExpr::constant(np, -1), &p_idx);
        // j1 - 1 in [0, p1-1]
        set.add_shifted_tile_membership(1, AffineExpr::constant(np, 1), p_idx[1]);
        let ctx = context(&sp, 2);
        let sym = count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        assert_eq!(sym.eval(&[4, 5, 2, 3]), 12, "paper Example 9: Vol(S7*1)=12");
        // And the paper's first chamber: 0<p0 ∧ 2p0<N0 ∧ p1>=2 ∧ 2p1<N1
        // → 4 p0 (p1 - 1). Try p0=2,N0=8,p1=3,N1=10: 4*2*2 = 16.
        assert_eq!(sym.eval(&[8, 10, 2, 3]), 16);
        // Second chamber: 2p0>=N0 → 2 N0 (p1-1): N0=3,p0=2,N1=10,p1=3 → 12.
        assert_eq!(sym.eval(&[3, 10, 2, 3]), 12);
        // Agreement with both oracles over a sweep.
        for n0 in 1..7 {
            for n1 in 1..7 {
                for p0 in 1..=n0 {
                    for p1 in 1..=n1 {
                        let params = [n0, n1, p0, p1];
                        let c = count_concrete(&set, &[2, 2], &params);
                        assert_eq!(sym.eval(&params), c, "params={params:?}");
                        assert_eq!(
                            count_bruteforce(&set, &[2, 2], &params),
                            c,
                            "params={params:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn symbolic_example9_s7_star_2() {
        // S7*2: inter-tile case. Space: base ∧ i1 >= 1 ∧
        //   j1 - (1 - p1) ∈ [0, p1-1]  ∧ k1 shifted by -1 in bounds:
        // the γ=(0,-1) variant reads from tile k1-1, valid when k1-1 >= 0,
        // i.e. k1 >= 1. Paper: Vol = 4 at N=(4,5), p=(2,3).
        let (sp, mut set) = base_space(&[2, 2]);
        let np = sp.len();
        let p_idx = [sp.p_index(0), sp.p_index(1)];
        set.add_global_affine(&[0, 1], AffineExpr::constant(np, -1), &p_idx);
        // j1 - (1 - p1) ∈ [0, p1 - 1]: off = 1 - p1 (affine).
        let off = (-&AffineExpr::param(np, p_idx[1])).plus(1);
        set.add_shifted_tile_membership(1, off, p_idx[1]);
        // k1 >= 1 (source tile exists)
        let mut c = crate::polyhedral::set::SetConstraint::zero(4, np);
        c.var_coeffs[set.kvar(1)] = AffineExpr::constant(np, 1);
        c.konst = AffineExpr::constant(np, -1);
        set.add(c);
        let ctx = context(&sp, 2);
        let sym = count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        assert_eq!(sym.eval(&[4, 5, 2, 3]), 4, "paper Example 9: Vol(S7*2)=4");
        // Paper chamber: 0 < p0 < N0/2 → 2 p0; p0 >= N0/2 → N0.
        assert_eq!(sym.eval(&[8, 10, 2, 3]), 4); // 2*p0 = 4
        assert_eq!(sym.eval(&[3, 10, 2, 3]), 3); // N0 = 3
        for n0 in 1..7 {
            for n1 in 1..7 {
                for p0 in 1..=n0 {
                    for p1 in 1..=n1 {
                        let params = [n0, n1, p0, p1];
                        assert_eq!(
                            sym.eval(&params),
                            count_concrete(&set, &[2, 2], &params),
                            "params={params:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn disjointified_form_matches() {
        let (sp, set) = base_space(&[2, 2]);
        let ctx = context(&sp, 2);
        let sym = count_symbolic(&set, &[2, 2], &ctx, &Default::default());
        let pw = sym
            .disjointify(&ctx, 256)
            .expect("base space should disjointify");
        assert!(!pw.is_empty());
        for n0 in (1..9).step_by(2) {
            for n1 in (1..9).step_by(3) {
                for p0 in 1..=n0 {
                    for p1 in 1..=n1 {
                        let params = [n0, n1, p0, p1];
                        assert_eq!(
                            pw.eval(&params),
                            sym.eval(&params),
                            "params={params:?}"
                        );
                    }
                }
            }
        }
    }
}
