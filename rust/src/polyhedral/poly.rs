//! Multivariate polynomials over the symbolic parameters — **packed
//! representation**.
//!
//! Volumes of the tiled statement spaces are products of per-dimension
//! interval lengths, each affine in `(N, p)` — so volumes are polynomials of
//! degree at most the loop depth per chamber (quasi-polynomial across
//! chambers, see [`super::piecewise`]). Coefficients are `i128`: products of
//! a few `i64` affine forms stay comfortably inside.
//!
//! # Packed exponent encoding
//!
//! A monomial's exponent vector is encoded into a single `u64` key: with
//! `n ≤ 8` parameters each exponent occupies an 8-bit lane, parameter 0 in
//! the most significant lane (so ascending key order equals ascending
//! lexicographic order of exponent vectors — the same normal form the old
//! `BTreeMap<Vec<u32>, _>` representation had). Spaces with more than 8
//! parameters fall back gracefully to narrower lanes (`⌊64/n⌋` bits each,
//! up to 64 parameters); exponents that do not fit a lane panic loudly
//! rather than silently corrupting a key. Terms live in a `Vec<(u64, i128)>`
//! sorted by key with no zero coefficients, so
//!
//! * `==` stays structural equality of polynomials,
//! * `add`/`sub` are single-pass sorted merges (one allocation, no
//!   per-term heap traffic),
//! * `mul` is a row-merge: for each left term the right-hand terms shifted
//!   by a lane-wise key addition are merged into the accumulator — the
//!   inner loop performs **zero allocations** (the old representation
//!   allocated one exponent `Vec` per term pair),
//! * `eval` is a recursive multivariate Horner scheme over the sorted key
//!   order, with every multiplication and addition checked.
//!
//! All arithmetic (`add`, `sub`, `mul`, `scale`, `eval`) is overflow-checked
//! and panics with the same message on `i128` overflow.

use std::fmt;

use super::expr::{AffineExpr, ParamSpace};

/// Exponent vector (unpacked view): `expo[i]` is the power of parameter
/// `P_i`. Only used at the edges (construction, iteration, display); the
/// in-memory representation is the packed `u64` key.
pub type Expo = Vec<u32>;

/// The one overflow panic message shared by all checked `Poly` arithmetic.
const OVERFLOW: &str = "poly arithmetic overflow";

/// Bits per exponent lane for a space with `nparams` parameters: 8 for the
/// common `≤ 8`-parameter loop nests, narrower beyond that.
#[inline]
fn lane_bits(nparams: usize) -> u32 {
    if nparams == 0 {
        return 64; // constant-only polynomials; no lane is ever shifted
    }
    assert!(
        nparams <= 64,
        "packed Poly supports at most 64 parameters, got {nparams}"
    );
    (64 / nparams as u32).min(8)
}

/// Largest exponent a lane of `bits` bits can hold.
#[inline]
fn lane_max(bits: u32) -> u64 {
    if bits >= 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

/// Shift of parameter `i`'s lane (parameter 0 is most significant).
#[inline]
fn lane_shift(nparams: usize, bits: u32, i: usize) -> u32 {
    ((nparams - 1 - i) as u32) * bits
}

/// Pack an exponent vector into a key. Panics if an exponent exceeds the
/// lane capacity.
fn pack(nparams: usize, bits: u32, expo: &[u32]) -> u64 {
    debug_assert_eq!(expo.len(), nparams);
    let max = lane_max(bits);
    let mut key = 0u64;
    for (i, &e) in expo.iter().enumerate() {
        assert!(
            e as u64 <= max,
            "exponent {e} exceeds packed lane capacity {max} \
             ({nparams} params, {bits}-bit lanes)"
        );
        key |= (e as u64) << lane_shift(nparams, bits, i);
    }
    key
}

/// Exponent of parameter `i` in a packed key.
#[inline]
fn unpack_lane(key: u64, nparams: usize, bits: u32, i: usize) -> u32 {
    ((key >> lane_shift(nparams, bits, i)) & lane_max(bits)) as u32
}

/// Key of the product of two monomials (lane-wise exponent addition),
/// checked lane by lane so an overflow can never carry silently.
fn mono_mul(nparams: usize, bits: u32, a: u64, b: u64) -> u64 {
    let max = lane_max(bits);
    for i in 0..nparams {
        let sh = lane_shift(nparams, bits, i);
        let ea = (a >> sh) & max;
        let eb = (b >> sh) & max;
        assert!(
            ea + eb <= max,
            "exponent {ea}+{eb} exceeds packed lane capacity {max} \
             ({nparams} params, {bits}-bit lanes)"
        );
    }
    // No lane overflows, so plain u64 addition IS lane-wise addition.
    a + b
}

/// A multivariate polynomial `Σ coeff · Π P_i^{e_i}` over a [`ParamSpace`].
///
/// Stored sparsely as a key-sorted vector of packed terms; zero
/// coefficients are never stored (normal form), so `==` is structural
/// equality of polynomials.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Poly {
    nparams: usize,
    bits: u32,
    /// Sorted by packed key; no zero coefficients.
    terms: Vec<(u64, i128)>,
}

impl Poly {
    /// The zero polynomial.
    pub fn zero(nparams: usize) -> Self {
        Poly { nparams, bits: lane_bits(nparams), terms: Vec::new() }
    }

    /// A constant polynomial.
    pub fn constant(nparams: usize, c: i128) -> Self {
        let mut p = Poly::zero(nparams);
        if c != 0 {
            p.terms.push((0, c));
        }
        p
    }

    /// Lift an affine expression to a polynomial.
    pub fn from_affine(e: &AffineExpr) -> Self {
        let n = e.nparams();
        let mut p = Poly::zero(n);
        if e.konst != 0 {
            p.terms.push((0, e.konst as i128));
        }
        // Parameter i's key is a single bit in its lane; iterating i in
        // descending index order yields ascending keys (param 0 has the
        // most significant lane).
        for i in (0..n).rev() {
            let c = e.coeffs[i];
            if c != 0 {
                p.terms.push((1u64 << lane_shift(n, p.bits, i), c as i128));
            }
        }
        debug_assert!(p.terms.windows(2).all(|w| w[0].0 < w[1].0));
        p
    }

    /// Build from explicit `(exponent vector, coefficient)` terms
    /// (duplicates are summed, zeros dropped). Used by the persistent
    /// analysis cache and the differential test oracle.
    pub fn from_terms<I>(nparams: usize, terms: I) -> Self
    where
        I: IntoIterator<Item = (Expo, i128)>,
    {
        let mut p = Poly::zero(nparams);
        for (e, c) in terms {
            let key = pack(nparams, p.bits, &e);
            p.add_packed(key, c);
        }
        p
    }

    /// As [`Self::from_terms`], returning `None` instead of panicking
    /// when the parameter count or an exponent exceeds the packed
    /// encoding's capacity. This is the single authority on that
    /// capacity for untrusted inputs — the persistent cache's loading
    /// path must degrade to recomputation, never panic.
    pub fn try_from_terms<I>(nparams: usize, terms: I) -> Option<Self>
    where
        I: IntoIterator<Item = (Expo, i128)>,
    {
        if nparams > 64 {
            return None;
        }
        let mut p = Poly::zero(nparams);
        let max = lane_max(p.bits);
        for (e, c) in terms {
            if e.len() != nparams || e.iter().any(|&x| x as u64 > max) {
                return None;
            }
            p.add_packed(pack(nparams, p.bits, &e), c);
        }
        Some(p)
    }

    /// Iterate terms as `(exponent vector, coefficient)` pairs in key
    /// (lexicographic) order.
    pub fn terms(&self) -> impl Iterator<Item = (Expo, i128)> + '_ {
        self.terms.iter().map(move |&(k, c)| {
            (
                (0..self.nparams)
                    .map(|i| unpack_lane(k, self.nparams, self.bits, i))
                    .collect(),
                c,
            )
        })
    }

    /// Number of stored (non-zero) terms.
    pub fn num_terms(&self) -> usize {
        self.terms.len()
    }

    /// Number of parameters of the underlying space.
    pub fn nparams(&self) -> usize {
        self.nparams
    }

    /// True when this is the zero polynomial.
    pub fn is_zero(&self) -> bool {
        self.terms.is_empty()
    }

    /// The constant value, when the polynomial has degree 0.
    pub fn as_const(&self) -> Option<i128> {
        match self.terms.as_slice() {
            [] => Some(0),
            [(0, c)] => Some(*c),
            _ => None,
        }
    }

    /// Total degree (0 for the zero polynomial).
    pub fn degree(&self) -> u32 {
        self.terms
            .iter()
            .map(|&(k, _)| {
                (0..self.nparams)
                    .map(|i| unpack_lane(k, self.nparams, self.bits, i))
                    .sum::<u32>()
            })
            .max()
            .unwrap_or(0)
    }

    /// Add `coeff` to the term with packed key `key`, removing the entry
    /// outright if it cancels to zero (no re-scan).
    fn add_packed(&mut self, key: u64, coeff: i128) {
        if coeff == 0 {
            return;
        }
        match self.terms.binary_search_by_key(&key, |t| t.0) {
            Ok(i) => {
                let v = self.terms[i].1.checked_add(coeff).expect(OVERFLOW);
                if v == 0 {
                    self.terms.remove(i);
                } else {
                    self.terms[i].1 = v;
                }
            }
            Err(i) => self.terms.insert(i, (key, coeff)),
        }
    }

    /// Single-pass sorted merge `self + sign·rhs`.
    fn merged(&self, rhs: &Poly, sign: i128) -> Poly {
        debug_assert_eq!(self.nparams, rhs.nparams);
        let (a, b) = (&self.terms, &rhs.terms);
        let mut out: Vec<(u64, i128)> = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.len() && j < b.len() {
            match a[i].0.cmp(&b[j].0) {
                std::cmp::Ordering::Less => {
                    out.push(a[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    let c = b[j].1.checked_mul(sign).expect(OVERFLOW);
                    out.push((b[j].0, c));
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    let c = a[i]
                        .1
                        .checked_add(
                            b[j].1.checked_mul(sign).expect(OVERFLOW),
                        )
                        .expect(OVERFLOW);
                    if c != 0 {
                        out.push((a[i].0, c));
                    }
                    i += 1;
                    j += 1;
                }
            }
        }
        out.extend_from_slice(&a[i..]);
        for &(k, c) in &b[j..] {
            out.push((k, c.checked_mul(sign).expect(OVERFLOW)));
        }
        Poly { nparams: self.nparams, bits: self.bits, terms: out }
    }

    /// `self + rhs`.
    pub fn add(&self, rhs: &Poly) -> Poly {
        self.merged(rhs, 1)
    }

    /// `self - rhs`.
    pub fn sub(&self, rhs: &Poly) -> Poly {
        self.merged(rhs, -1)
    }

    /// `self += rhs` in place (binary-search inserts for small `rhs`, one
    /// sorted merge otherwise).
    pub fn add_assign(&mut self, rhs: &Poly) {
        debug_assert_eq!(self.nparams, rhs.nparams);
        if rhs.terms.len() <= 4 {
            for &(k, c) in &rhs.terms {
                self.add_packed(k, c);
            }
        } else {
            *self = self.merged(rhs, 1);
        }
    }

    /// `self -= rhs` in place.
    pub fn sub_assign(&mut self, rhs: &Poly) {
        debug_assert_eq!(self.nparams, rhs.nparams);
        if rhs.terms.len() <= 4 {
            for &(k, c) in &rhs.terms {
                self.add_packed(k, c.checked_neg().expect(OVERFLOW));
            }
        } else {
            *self = self.merged(rhs, -1);
        }
    }

    /// `out += self · rhs`, allocation-free in the inner loop: each left
    /// term's product row (right-hand keys shifted by a lane-wise key
    /// addition, already sorted) is merged with the accumulator in one
    /// pass, double-buffered through a reused scratch vector.
    pub fn mul_into(&self, rhs: &Poly, out: &mut Poly) {
        debug_assert_eq!(self.nparams, rhs.nparams);
        debug_assert_eq!(self.nparams, out.nparams);
        if self.is_zero() || rhs.is_zero() {
            return;
        }
        let mut scratch: Vec<(u64, i128)> = Vec::new();
        for &(ka, ca) in &self.terms {
            scratch.clear();
            scratch.reserve(out.terms.len() + rhs.terms.len());
            let acc = &out.terms;
            let (mut i, mut j) = (0usize, 0usize);
            while i < acc.len() && j < rhs.terms.len() {
                let (kb, cb) = rhs.terms[j];
                let key = mono_mul(self.nparams, self.bits, ka, kb);
                match acc[i].0.cmp(&key) {
                    std::cmp::Ordering::Less => {
                        scratch.push(acc[i]);
                        i += 1;
                    }
                    std::cmp::Ordering::Greater => {
                        scratch
                            .push((key, ca.checked_mul(cb).expect(OVERFLOW)));
                        j += 1;
                    }
                    std::cmp::Ordering::Equal => {
                        let c = acc[i]
                            .1
                            .checked_add(
                                ca.checked_mul(cb).expect(OVERFLOW),
                            )
                            .expect(OVERFLOW);
                        if c != 0 {
                            scratch.push((acc[i].0, c));
                        }
                        i += 1;
                        j += 1;
                    }
                }
            }
            scratch.extend_from_slice(&acc[i..]);
            for &(kb, cb) in &rhs.terms[j..] {
                scratch.push((
                    mono_mul(self.nparams, self.bits, ka, kb),
                    ca.checked_mul(cb).expect(OVERFLOW),
                ));
            }
            std::mem::swap(&mut out.terms, &mut scratch);
        }
    }

    /// `self · rhs`.
    pub fn mul(&self, rhs: &Poly) -> Poly {
        let mut out = Poly::zero(self.nparams);
        self.mul_into(rhs, &mut out);
        out
    }

    /// `self · c` for an integer constant (checked).
    pub fn scale(&self, c: i128) -> Poly {
        if c == 0 {
            return Poly::zero(self.nparams);
        }
        Poly {
            nparams: self.nparams,
            bits: self.bits,
            terms: self
                .terms
                .iter()
                .map(|&(k, v)| (k, v.checked_mul(c).expect(OVERFLOW)))
                .collect(),
        }
    }

    /// Evaluate at a concrete parameter point by recursive multivariate
    /// Horner over the key-sorted terms: `P = Σ_e P0^e · Q_e(P1, …)`
    /// becomes `(((Q_{e1}·P0^{e1-e2} + Q_{e2})·P0^{e2-e3} + …)·P0^{e_m})`,
    /// one checked multiplication per exponent step instead of a fresh
    /// power chain per term.
    pub fn eval(&self, params: &[i64]) -> i128 {
        debug_assert_eq!(params.len(), self.nparams);
        if self.terms.is_empty() {
            return 0;
        }
        self.horner(&self.terms, 0, params)
    }

    fn horner(
        &self,
        terms: &[(u64, i128)],
        lane: usize,
        params: &[i64],
    ) -> i128 {
        if lane == self.nparams {
            // All exponents consumed; keys are unique, so exactly one term.
            debug_assert_eq!(terms.len(), 1);
            return terms[0].1;
        }
        let x = params[lane] as i128;
        let mut acc: i128 = 0;
        let mut prev_e: Option<u32> = None;
        // Terms within `terms` share all lanes above `lane`, so runs of
        // equal `lane`-exponents are contiguous; walk them high-to-low.
        let mut hi = terms.len();
        while hi > 0 {
            let e =
                unpack_lane(terms[hi - 1].0, self.nparams, self.bits, lane);
            let mut lo = hi - 1;
            while lo > 0
                && unpack_lane(terms[lo - 1].0, self.nparams, self.bits, lane)
                    == e
            {
                lo -= 1;
            }
            if let Some(pe) = prev_e {
                acc = pow_mul(acc, x, pe - e);
            }
            acc = acc
                .checked_add(self.horner(&terms[lo..hi], lane + 1, params))
                .expect(OVERFLOW);
            prev_e = Some(e);
            hi = lo;
        }
        if let Some(e) = prev_e {
            acc = pow_mul(acc, x, e);
        }
        acc
    }

    /// Evaluate to f64 (used when combining with energy weights in pJ).
    pub fn eval_f64(&self, params: &[i64]) -> f64 {
        self.eval(params) as f64
    }

    /// Pretty-print against a parameter space.
    pub fn display<'a>(&'a self, space: &'a ParamSpace) -> PolyDisplay<'a> {
        PolyDisplay { poly: self, space }
    }
}

/// `acc · x^k`, checked.
fn pow_mul(mut acc: i128, x: i128, k: u32) -> i128 {
    for _ in 0..k {
        acc = acc.checked_mul(x).expect(OVERFLOW);
    }
    acc
}

/// Helper for `{}`-formatting a [`Poly`] with parameter names.
pub struct PolyDisplay<'a> {
    poly: &'a Poly,
    space: &'a ParamSpace,
}

impl fmt::Display for PolyDisplay<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.poly.terms.is_empty() {
            return write!(f, "0");
        }
        // Print highest-degree terms first for readability (stable sort on
        // the lex-ascending key order, exactly the old normal form).
        let mut terms: Vec<(Expo, i128)> = self.poly.terms().collect();
        terms.sort_by_key(|(e, _)| std::cmp::Reverse(e.iter().sum::<u32>()));
        for (idx, (e, c)) in terms.iter().enumerate() {
            let is_const_term = e.iter().all(|&x| x == 0);
            if idx > 0 {
                write!(f, " {} ", if *c < 0 { "-" } else { "+" })?;
            } else if *c < 0 {
                write!(f, "-")?;
            }
            let a = c.unsigned_abs();
            if a != 1 || is_const_term {
                write!(f, "{a}")?;
            }
            for (i, &pow) in e.iter().enumerate() {
                if pow == 0 {
                    continue;
                }
                write!(f, "{}", self.space.name(i))?;
                if pow > 1 {
                    write!(f, "^{pow}")?;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s() -> ParamSpace {
        ParamSpace::loop_nest(2) // N0 N1 p0 p1
    }

    fn aff(coeffs: [i64; 4], k: i64) -> AffineExpr {
        AffineExpr { coeffs: coeffs.to_vec(), konst: k }
    }

    #[test]
    fn from_affine_and_eval() {
        let e = aff([2, 0, -1, 0], 3); // 2N0 - p0 + 3
        let p = Poly::from_affine(&e);
        assert_eq!(p.degree(), 1);
        assert_eq!(p.eval(&[5, 0, 4, 0]), (2 * 5 - 4 + 3) as i128);
    }

    #[test]
    fn mul_matches_eval() {
        let a = Poly::from_affine(&aff([1, 0, 0, 0], -1)); // N0 - 1
        let b = Poly::from_affine(&aff([0, 1, 0, -2], 0)); // N1 - 2p1
        let prod = a.mul(&b);
        assert_eq!(prod.degree(), 2);
        let pt = [7, 9, 3, 2];
        assert_eq!(prod.eval(&pt), a.eval(&pt) * b.eval(&pt));
    }

    #[test]
    fn add_sub_cancel_to_zero() {
        let a = Poly::from_affine(&aff([1, 2, 3, 4], 5));
        let z = a.sub(&a);
        assert!(z.is_zero());
        assert_eq!(z, Poly::zero(4));
        assert_eq!(a.add(&z), a);
    }

    #[test]
    fn in_place_ops_match_functional_ones() {
        let a = Poly::from_affine(&aff([1, 0, -2, 0], 3));
        let b = Poly::from_affine(&aff([0, 2, 0, 1], -1));
        let mut x = a.clone();
        x.add_assign(&b);
        assert_eq!(x, a.add(&b));
        x.sub_assign(&b);
        assert_eq!(x, a);
        let mut acc = a.mul(&b);
        a.mul_into(&b, &mut acc); // acc = 2·a·b
        assert_eq!(acc, a.mul(&b).scale(2));
    }

    #[test]
    fn cancelled_term_is_removed_outright() {
        // a + b - b leaves exactly a's terms, no zero-coefficient entries.
        let a = Poly::from_affine(&aff([1, 0, 0, 0], 0));
        let b = Poly::from_affine(&aff([0, 1, 0, 0], 7));
        let mut x = a.add(&b);
        x.sub_assign(&b);
        assert_eq!(x.num_terms(), 1);
        assert_eq!(x, a);
    }

    #[test]
    fn normal_form_equality() {
        // (N0+1)(N0-1) == N0^2 - 1 structurally.
        let n0 = Poly::from_affine(&aff([1, 0, 0, 0], 0));
        let lhs = Poly::from_affine(&aff([1, 0, 0, 0], 1))
            .mul(&Poly::from_affine(&aff([1, 0, 0, 0], -1)));
        let rhs = n0.mul(&n0).sub(&Poly::constant(4, 1));
        assert_eq!(lhs, rhs);
    }

    #[test]
    fn as_const_paths() {
        assert_eq!(Poly::zero(4).as_const(), Some(0));
        assert_eq!(Poly::constant(4, 42).as_const(), Some(42));
        let n0 = Poly::from_affine(&aff([1, 0, 0, 0], 0));
        assert_eq!(n0.as_const(), None);
    }

    #[test]
    fn display_readable() {
        let sp = s();
        let p = Poly::from_affine(&aff([1, 0, 0, 0], 0))
            .mul(&Poly::from_affine(&aff([0, 1, 0, 0], -2)));
        // N0·(N1-2) = N0N1 - 2N0
        assert_eq!(format!("{}", p.display(&sp)), "N0N1 - 2N0");
        assert_eq!(format!("{}", Poly::zero(4).display(&sp)), "0");
    }

    #[test]
    fn scale_and_eval_f64() {
        let p = Poly::constant(4, 6).scale(-2);
        assert_eq!(p.as_const(), Some(-12));
        assert_eq!(p.eval_f64(&[0, 0, 0, 0]), -12.0);
    }

    #[test]
    fn terms_round_trip_through_from_terms() {
        let a = Poly::from_affine(&aff([2, -1, 0, 3], 5))
            .mul(&Poly::from_affine(&aff([0, 1, 1, 0], -2)));
        let rebuilt = Poly::from_terms(4, a.terms());
        assert_eq!(rebuilt, a);
    }

    #[test]
    fn try_from_terms_rejects_unpackable_input_without_panicking() {
        let a = Poly::from_affine(&aff([2, -1, 0, 3], 5));
        assert_eq!(Poly::try_from_terms(4, a.terms()), Some(a));
        // Exponent past the 8-bit lane, wrong arity, too many params.
        assert_eq!(Poly::try_from_terms(4, [(vec![256, 0, 0, 0], 1)]), None);
        assert_eq!(Poly::try_from_terms(4, [(vec![1, 0], 1)]), None);
        assert_eq!(
            Poly::try_from_terms(65, std::iter::empty::<(Expo, i128)>()),
            None
        );
    }

    #[test]
    fn horner_eval_handles_high_degree_and_large_values() {
        // p0·p1 monomial at p = 2^32 → 2^64, well past i64 (the schedule
        // scalability regression relies on this staying exact).
        let p0 = Poly::from_affine(&aff([0, 0, 1, 0], 0));
        let p1 = Poly::from_affine(&aff([0, 0, 0, 1], 0));
        let prod = p0.mul(&p1);
        let n = 1i64 << 32;
        assert_eq!(prod.eval(&[0, 0, n, n]), 1i128 << 64);
        // Degree-4 mixed term with interleaved lower-degree terms.
        let q = prod.mul(&prod).add(&p0).sub(&Poly::constant(4, 9));
        let pt = [3, 7, 5, 4];
        assert_eq!(q.eval(&pt), (5i128 * 4).pow(2) + 5 - 9);
    }

    #[test]
    #[should_panic(expected = "poly arithmetic overflow")]
    fn checked_scale_panics_on_overflow() {
        Poly::constant(4, i128::MAX).scale(2);
    }

    #[test]
    #[should_panic(expected = "poly arithmetic overflow")]
    fn checked_eval_panics_on_overflow() {
        // (p0·p1)^2 at 2^32 → 2^128 overflows i128.
        let p0 = Poly::from_affine(&aff([0, 0, 1, 0], 0));
        let p1 = Poly::from_affine(&aff([0, 0, 0, 1], 0));
        let prod = p0.mul(&p1);
        let sq = prod.mul(&prod);
        let n = 1i64 << 32;
        sq.eval(&[0, 0, n, n]);
    }

    #[test]
    fn narrow_lane_fallback_beyond_eight_params() {
        // 10 parameters → 6-bit lanes; arithmetic still exact.
        let n = 10usize;
        let mut e1 = AffineExpr::zero(n);
        e1.coeffs[0] = 1;
        e1.konst = 1;
        let mut e2 = AffineExpr::zero(n);
        e2.coeffs[9] = 2;
        let a = Poly::from_affine(&e1);
        let b = Poly::from_affine(&e2);
        let prod = a.mul(&b); // (P0+1)·2P9
        let mut pt = vec![0i64; n];
        pt[0] = 4;
        pt[9] = 3;
        assert_eq!(prod.eval(&pt), ((4 + 1) * 2 * 3) as i128);
        assert_eq!(prod.degree(), 2);
    }
}
