//! Multivariate polynomials over the symbolic parameters.
//!
//! Volumes of the tiled statement spaces are products of per-dimension
//! interval lengths, each affine in `(N, p)` — so volumes are polynomials of
//! degree at most the loop depth per chamber (quasi-polynomial across
//! chambers, see [`super::piecewise`]). Coefficients are `i128`: products of
//! a few `i64` affine forms stay comfortably inside.

use std::collections::BTreeMap;
use std::fmt;

use super::expr::{AffineExpr, ParamSpace};

/// Exponent vector: `expo[i]` is the power of parameter `P_i`.
pub type Expo = Vec<u32>;

/// A multivariate polynomial `Σ coeff · Π P_i^{e_i}` over a [`ParamSpace`].
///
/// Stored sparsely as a map from exponent vector to coefficient; zero
/// coefficients are never stored (normal form), so `==` is structural
/// equality of polynomials.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Poly {
    nparams: usize,
    terms: BTreeMap<Expo, i128>,
}

impl Poly {
    /// The zero polynomial.
    pub fn zero(nparams: usize) -> Self {
        Poly { nparams, terms: BTreeMap::new() }
    }

    /// A constant polynomial.
    pub fn constant(nparams: usize, c: i128) -> Self {
        let mut p = Poly::zero(nparams);
        if c != 0 {
            p.terms.insert(vec![0; nparams], c);
        }
        p
    }

    /// Lift an affine expression to a polynomial.
    pub fn from_affine(e: &AffineExpr) -> Self {
        let n = e.nparams();
        let mut p = Poly::zero(n);
        if e.konst != 0 {
            p.terms.insert(vec![0; n], e.konst as i128);
        }
        for (i, &c) in e.coeffs.iter().enumerate() {
            if c != 0 {
                let mut ex = vec![0; n];
                ex[i] = 1;
                p.terms.insert(ex, c as i128);
            }
        }
        p
    }

    /// Number of parameters of the underlying space.
    pub fn nparams(&self) -> usize {
        self.nparams
    }

    /// True when this is the zero polynomial.
    pub fn is_zero(&self) -> bool {
        self.terms.is_empty()
    }

    /// The constant value, when the polynomial has degree 0.
    pub fn as_const(&self) -> Option<i128> {
        match self.terms.len() {
            0 => Some(0),
            1 => {
                let (e, &c) = self.terms.iter().next().unwrap();
                if e.iter().all(|&x| x == 0) {
                    Some(c)
                } else {
                    None
                }
            }
            _ => None,
        }
    }

    /// Total degree (0 for the zero polynomial).
    pub fn degree(&self) -> u32 {
        self.terms
            .keys()
            .map(|e| e.iter().sum::<u32>())
            .max()
            .unwrap_or(0)
    }

    fn add_term(&mut self, expo: Expo, coeff: i128) {
        if coeff == 0 {
            return;
        }
        let entry = self.terms.entry(expo).or_insert(0);
        *entry += coeff;
        if *entry == 0 {
            // keep normal form: remove cancelled terms
            let key: Vec<u32> = self
                .terms
                .iter()
                .find(|(_, &v)| v == 0)
                .map(|(k, _)| k.clone())
                .unwrap();
            self.terms.remove(&key);
        }
    }

    /// `self + rhs`.
    pub fn add(&self, rhs: &Poly) -> Poly {
        debug_assert_eq!(self.nparams, rhs.nparams);
        let mut out = self.clone();
        for (e, &c) in &rhs.terms {
            out.add_term(e.clone(), c);
        }
        out
    }

    /// `self - rhs`.
    pub fn sub(&self, rhs: &Poly) -> Poly {
        debug_assert_eq!(self.nparams, rhs.nparams);
        let mut out = self.clone();
        for (e, &c) in &rhs.terms {
            out.add_term(e.clone(), -c);
        }
        out
    }

    /// `self · rhs`.
    pub fn mul(&self, rhs: &Poly) -> Poly {
        debug_assert_eq!(self.nparams, rhs.nparams);
        let mut out = Poly::zero(self.nparams);
        for (ea, &ca) in &self.terms {
            for (eb, &cb) in &rhs.terms {
                let expo: Expo = ea.iter().zip(eb).map(|(a, b)| a + b).collect();
                out.add_term(expo, ca.checked_mul(cb).expect("poly coeff overflow"));
            }
        }
        out
    }

    /// `self · c` for an integer constant.
    pub fn scale(&self, c: i128) -> Poly {
        let mut out = Poly::zero(self.nparams);
        for (e, &v) in &self.terms {
            out.add_term(e.clone(), v * c);
        }
        out
    }

    /// Evaluate at a concrete parameter point.
    pub fn eval(&self, params: &[i64]) -> i128 {
        debug_assert_eq!(params.len(), self.nparams);
        let mut acc: i128 = 0;
        for (e, &c) in &self.terms {
            let mut t = c;
            for (i, &pow) in e.iter().enumerate() {
                for _ in 0..pow {
                    t = t.checked_mul(params[i] as i128).expect("poly eval overflow");
                }
            }
            acc += t;
        }
        acc
    }

    /// Evaluate to f64 (used when combining with energy weights in pJ).
    pub fn eval_f64(&self, params: &[i64]) -> f64 {
        self.eval(params) as f64
    }

    /// Pretty-print against a parameter space.
    pub fn display<'a>(&'a self, space: &'a ParamSpace) -> PolyDisplay<'a> {
        PolyDisplay { poly: self, space }
    }
}

/// Helper for `{}`-formatting a [`Poly`] with parameter names.
pub struct PolyDisplay<'a> {
    poly: &'a Poly,
    space: &'a ParamSpace,
}

impl fmt::Display for PolyDisplay<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.poly.terms.is_empty() {
            return write!(f, "0");
        }
        // Print highest-degree terms first for readability.
        let mut terms: Vec<(&Expo, &i128)> = self.poly.terms.iter().collect();
        terms.sort_by_key(|(e, _)| std::cmp::Reverse(e.iter().sum::<u32>()));
        for (idx, (e, &c)) in terms.iter().enumerate() {
            let is_const_term = e.iter().all(|&x| x == 0);
            if idx > 0 {
                write!(f, " {} ", if c < 0 { "-" } else { "+" })?;
            } else if c < 0 {
                write!(f, "-")?;
            }
            let a = c.unsigned_abs();
            if a != 1 || is_const_term {
                write!(f, "{a}")?;
            }
            for (i, &pow) in e.iter().enumerate() {
                if pow == 0 {
                    continue;
                }
                write!(f, "{}", self.space.name(i))?;
                if pow > 1 {
                    write!(f, "^{pow}")?;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s() -> ParamSpace {
        ParamSpace::loop_nest(2) // N0 N1 p0 p1
    }

    fn aff(coeffs: [i64; 4], k: i64) -> AffineExpr {
        AffineExpr { coeffs: coeffs.to_vec(), konst: k }
    }

    #[test]
    fn from_affine_and_eval() {
        let e = aff([2, 0, -1, 0], 3); // 2N0 - p0 + 3
        let p = Poly::from_affine(&e);
        assert_eq!(p.degree(), 1);
        assert_eq!(p.eval(&[5, 0, 4, 0]), (2 * 5 - 4 + 3) as i128);
    }

    #[test]
    fn mul_matches_eval() {
        let a = Poly::from_affine(&aff([1, 0, 0, 0], -1)); // N0 - 1
        let b = Poly::from_affine(&aff([0, 1, 0, -2], 0)); // N1 - 2p1
        let prod = a.mul(&b);
        assert_eq!(prod.degree(), 2);
        let pt = [7, 9, 3, 2];
        assert_eq!(prod.eval(&pt), a.eval(&pt) * b.eval(&pt));
    }

    #[test]
    fn add_sub_cancel_to_zero() {
        let a = Poly::from_affine(&aff([1, 2, 3, 4], 5));
        let z = a.sub(&a);
        assert!(z.is_zero());
        assert_eq!(z, Poly::zero(4));
        assert_eq!(a.add(&z), a);
    }

    #[test]
    fn normal_form_equality() {
        // (N0+1)(N0-1) == N0^2 - 1 structurally.
        let n0 = Poly::from_affine(&aff([1, 0, 0, 0], 0));
        let lhs = Poly::from_affine(&aff([1, 0, 0, 0], 1))
            .mul(&Poly::from_affine(&aff([1, 0, 0, 0], -1)));
        let rhs = n0.mul(&n0).sub(&Poly::constant(4, 1));
        assert_eq!(lhs, rhs);
    }

    #[test]
    fn as_const_paths() {
        assert_eq!(Poly::zero(4).as_const(), Some(0));
        assert_eq!(Poly::constant(4, 42).as_const(), Some(42));
        let n0 = Poly::from_affine(&aff([1, 0, 0, 0], 0));
        assert_eq!(n0.as_const(), None);
    }

    #[test]
    fn display_readable() {
        let sp = s();
        let p = Poly::from_affine(&aff([1, 0, 0, 0], 0))
            .mul(&Poly::from_affine(&aff([0, 1, 0, 0], -2)));
        // N0·(N1-2) = N0N1 - 2N0
        assert_eq!(format!("{}", p.display(&sp)), "N0N1 - 2N0");
        assert_eq!(format!("{}", Poly::zero(4).display(&sp)), "0");
    }

    #[test]
    fn scale_and_eval_f64() {
        let p = Poly::constant(4, 6).scale(-2);
        assert_eq!(p.as_const(), Some(-12));
        assert_eq!(p.eval_f64(&[0, 0, 0, 0]), -12.0);
    }
}
