//! Latency evaluation (Eq. 8 of the paper) and the single-iteration
//! critical chain `L_c`.

use crate::pra::{Pra, Rdg};
use crate::tiling::TiledPra;

use super::vectors::Schedule;

/// `L_c = max_q(τ_q + w_q)`: the longest chain of intra-iteration
/// (zero-dependence) statement executions, with unit latency per statement
/// (`w_q = 1`, as in the paper's Example 3).
pub fn critical_chain(pra: &Pra) -> i64 {
    let rdg = Rdg::build(pra);
    let nq = pra.statements.len();
    let order = rdg
        .intra_iteration_order(nq)
        .expect("PRA has an intra-iteration dependence cycle");
    // Longest path in node count over zero-dep edges.
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); nq];
    for e in &rdg.edges {
        if let Some(from) = e.from {
            if e.dep.iter().all(|&d| d == 0) && from != e.to {
                adj[from].push(e.to);
            }
        }
    }
    let mut depth = vec![1i64; nq];
    for &q in &order {
        for &nxt in &adj[q] {
            depth[nxt] = depth[nxt].max(depth[q] + 1);
        }
    }
    depth.into_iter().max().unwrap_or(0)
}

/// Global latency `L = λ^J·(p−1) + λ^K·(t−1) + L_c` (Eq. 8) at concrete
/// parameters.
///
/// The sum is computed in `i128` end-to-end (λ entries themselves can
/// exceed `i64` at large symbolic parameters) and converted once at the
/// end; a latency beyond `i64` cycles is unrepresentable for every
/// downstream consumer and fails loudly instead of wrapping.
pub fn latency(schedule: &Schedule, tiled: &TiledPra, params: &[i64]) -> i64 {
    let n = tiled.pra.ndims;
    let lj = schedule.lambda_j_at(params);
    let lk = schedule.lambda_k_at(params);
    let mut l = schedule.lc as i128;
    for dim in 0..n {
        let p_l = params[tiled.pra.space.p_index(dim)];
        l += lj[dim] * (p_l as i128 - 1);
        l += lk[dim] * (tiled.mapping.t[dim] as i128 - 1);
    }
    i64::try_from(l).expect("global latency overflows i64 cycles")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::find_schedule;
    use crate::tiling::{tile_pra, ArrayMapping};
    use crate::workloads::gemm::gemm;
    use crate::workloads::gesummv::gesummv;

    #[test]
    fn gesummv_critical_chain_is_4() {
        // Paper Example 3: L_c = 4 (x → a → sA → Y).
        assert_eq!(critical_chain(&gesummv()), 4);
    }

    #[test]
    fn example3_latency_16() {
        // Paper Example 3: N = 4×5, p = (2,3), t = (2,2), π = 1 → L = 16.
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        assert_eq!(latency(&s, &tiled, &[4, 5, 2, 3]), 16);
    }

    #[test]
    fn latency_grows_with_problem_size() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        let mut prev = 0;
        for h in 1..6 {
            let n = 4 * h;
            let params = tiled.mapping.params_for(&[n, n]);
            let l = latency(&s, &tiled, &params);
            assert!(l > prev, "latency must increase: {l} after {prev}");
            prev = l;
        }
    }

    #[test]
    fn gemm_latency_dominated_by_reduction() {
        // GEMM on 2×2×1: the reduction dim stays inside the PE, so latency
        // scales with N0·N1·N2 / #PEs to first order.
        let tiled = tile_pra(&gemm(), &ArrayMapping::new(vec![2, 2, 1]));
        let s = find_schedule(&tiled, 1).unwrap();
        let params = tiled.mapping.params_for(&[8, 8, 8]);
        let l = latency(&s, &tiled, &params);
        // one tile is 4·4·8 = 128 iterations, sequential ⇒ L ≥ 128.
        assert!(l >= 128, "L = {l}");
        assert!(l < 4 * 128, "L = {l} should not serialize all tiles");
    }
}
