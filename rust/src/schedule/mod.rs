//! Symbolic LSGP scheduling (§III-D of the paper).
//!
//! Iterations inside a tile execute sequentially (initiation interval `π`)
//! in a lexicographic order given by a dimension permutation; tiles execute
//! in parallel, offset by the inter-tile schedule vector `λ^K`. Both
//! vectors are *symbolic* — their entries are (products of) tile-size
//! parameters — and the global latency follows Eq. 8:
//!
//! ```text
//! L = λ^J·(p−1) + λ^K·(t−1) + L_c
//! ```
//!
//! One mapping generally admits several feasible schedules (one per
//! causal dimension permutation): [`find_schedule`] picks the first,
//! [`enumerate_schedules`] yields them all — the DSE schedule axis.

pub mod latency;
pub mod vectors;

pub use latency::{critical_chain, latency};
pub use vectors::{
    enumerate_schedules, find_schedule, Schedule, ScheduleError,
};
