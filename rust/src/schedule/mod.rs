//! Symbolic LSGP scheduling (§III-D of the paper).
//!
//! Iterations inside a tile execute sequentially (initiation interval `π`)
//! in a lexicographic order given by a dimension permutation; tiles execute
//! in parallel, offset by the inter-tile schedule vector `λ^K`. Both
//! vectors are *symbolic* — their entries are (products of) tile-size
//! parameters — and the global latency follows Eq. 8:
//!
//! ```text
//! L = λ^J·(p−1) + λ^K·(t−1) + L_c
//! ```

pub mod latency;
pub mod vectors;

pub use latency::{critical_chain, latency};
pub use vectors::{find_schedule, Schedule, ScheduleError};
