//! Construction of the symbolic schedule vectors `(λ^J, λ^K)`.
//!
//! `λ^J` realizes a sequential lexicographic walk of the tile in a chosen
//! dimension permutation (fastest dimension first): `λ^J_{σ(m)} =
//! π·Π_{r<m} p_{σ(r)}`. The permutation must make every dependence vector
//! "mixed-radix positive" — its most significant non-zero component (in
//! σ-order) positive — which is exactly intra-tile causality
//! `λ^J·d ≥ 1` for `|d_ℓ| < p_ℓ`.
//!
//! `λ^K` is the component-wise least vector satisfying the inter-tile
//! causality constraints `λ^J·d_J + λ^K·d_K ≥ π` contributed by every
//! tile-crossing statement variant (cf. Example 3 of the paper, where
//! GESUMMV on a 2×2 array yields `λ^J = (1, p0)`,
//! `λ^K = (p0, p0(p1−1)+1)`). Entries are kept as *candidate lists* of
//! polynomials whose pointwise maximum is the schedule entry — the maximum
//! of polynomials is chamber-dependent, and deferring it keeps the
//! construction fully symbolic.

use crate::polyhedral::Poly;
use crate::tiling::TiledPra;

use super::latency::critical_chain;

/// A symbolic LSGP schedule.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Intra-tile dimension order, fastest first.
    pub perm: Vec<usize>,
    /// Initiation interval between consecutive intra-tile iterations.
    pub pi: i64,
    /// `λ^J` per dimension (monomials in the tile sizes).
    pub lambda_j: Vec<Poly>,
    /// `λ^K` per dimension as candidate lists; the entry is
    /// `max(0, max(candidates))` evaluated per parameter point.
    pub lambda_k: Vec<Vec<Poly>>,
    /// Causality constraints with multi-dimensional `d_K` (diagonal tile
    /// crossings): `(d_K, required)` meaning `λ^K·d_K ≥ required`.
    /// Checked by [`Schedule::verify`].
    pub extra: Vec<(Vec<i64>, Poly)>,
    /// Single-iteration latency `L_c = max_q(τ_q + w_q)` (Eq. 8).
    pub lc: i64,
}

/// Scheduling failure.
#[derive(Debug)]
pub enum ScheduleError {
    NoValidPermutation(Vec<Vec<i64>>),
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::NoValidPermutation(deps) => write!(
                f,
                "no lexicographic dimension order satisfies all intra-tile \
                 dependencies: {deps:?}"
            ),
        }
    }
}

impl std::error::Error for ScheduleError {}

impl Schedule {
    /// Evaluate `λ^J` at concrete parameters.
    pub fn lambda_j_at(&self, params: &[i64]) -> Vec<i64> {
        self.lambda_j.iter().map(|p| p.eval(params) as i64).collect()
    }

    /// Evaluate `λ^K` at concrete parameters.
    ///
    /// Per-dimension base values come from the symbolic candidate lists;
    /// the multi-dimensional (diagonal tile-crossing) constraints in
    /// [`Schedule::extra`] are then enforced by a small monotone fixpoint:
    /// whenever `λ^K·d_K < required`, the highest-indexed dimension with
    /// `d_K > 0` is bumped by the deficit. The fixpoint terminates because
    /// every bump strictly increases one component and requirements are
    /// finite; lexicographic positivity of the dependencies guarantees a
    /// positive component exists in every lower-bound constraint.
    pub fn lambda_k_at(&self, params: &[i64]) -> Vec<i64> {
        let mut lk: Vec<i64> = self
            .lambda_k
            .iter()
            .map(|cands| {
                cands
                    .iter()
                    .map(|c| c.eval(params) as i64)
                    .max()
                    .unwrap_or(0)
                    .max(0)
            })
            .collect();
        for _round in 0..(4 * self.extra.len() + 4) {
            let mut changed = false;
            for (dk, req) in &self.extra {
                let need = req.eval(params) as i64;
                let have: i64 =
                    dk.iter().zip(&lk).map(|(d, l)| d * l).sum();
                if have < need {
                    if let Some(bump) =
                        (0..dk.len()).rev().find(|&l| dk[l] > 0)
                    {
                        lk[bump] += (need - have + dk[bump] - 1) / dk[bump];
                        changed = true;
                    }
                    // pure-negative d_K rows are upper bounds; they are
                    // checked by `verify`, not enforced here.
                }
            }
            if !changed {
                break;
            }
        }
        lk
    }

    /// Start time of iteration `(j, k)` (Eq. of §III-D:
    /// `t(j,k) = λ^J·j + λ^K·k`).
    pub fn start_time(&self, j: &[i64], k: &[i64], params: &[i64]) -> i64 {
        let lj = self.lambda_j_at(params);
        let lk = self.lambda_k_at(params);
        lj.iter().zip(j).map(|(a, b)| a * b).sum::<i64>()
            + lk.iter().zip(k).map(|(a, b)| a * b).sum::<i64>()
    }

    /// Check every causality constraint at concrete parameters. Returns
    /// violated constraint descriptions (empty = schedule valid there).
    pub fn verify(&self, tiled: &TiledPra, params: &[i64]) -> Vec<String> {
        let mut bad = Vec::new();
        let lj = self.lambda_j_at(params);
        let lk = self.lambda_k_at(params);
        for st in &tiled.statements {
            if st.gamma.is_none() {
                continue;
            }
            // Skip variants that never execute for this array size.
            let feasible = crate::polyhedral::count_concrete(
                &st.space,
                &tiled.mapping.t,
                params,
            ) > 0;
            if !feasible {
                continue;
            }
            let dj: i64 = st
                .dj
                .iter()
                .zip(&lj)
                .map(|(e, l)| e.eval(params) * l)
                .sum();
            let dk: i64 = st.dk.iter().zip(&lk).map(|(d, l)| d * l).sum();
            if dj + dk < self.pi {
                bad.push(format!(
                    "{}: λJ·dJ + λK·dK = {} < π = {} at {params:?}",
                    st.name,
                    dj + dk,
                    self.pi
                ));
            }
        }
        bad
    }
}

/// Find a symbolic schedule for a tiled PRA (π given; the paper's
/// experiments use π = 1).
pub fn find_schedule(tiled: &TiledPra, pi: i64) -> Result<Schedule, ScheduleError> {
    let n = tiled.pra.ndims;
    let np = tiled.pra.space.len();
    let p_idx: Vec<usize> =
        (0..n).map(|l| tiled.pra.space.p_index(l)).collect();

    // All distinct original dependence vectors.
    let mut deps: Vec<Vec<i64>> = tiled
        .statements
        .iter()
        .filter(|s| s.d.iter().any(|&x| x != 0))
        .map(|s| s.d.clone())
        .collect();
    deps.sort();
    deps.dedup();

    // 1. Choose the dimension permutation (natural order preferred, which
    //    reproduces the paper's λ^J for GESUMMV).
    let perm = permutations(n)
        .into_iter()
        .find(|perm| {
            deps.iter().all(|d| {
                // most significant non-zero (scanning slowest→fastest)
                for &dim in perm.iter().rev() {
                    match d[dim].signum() {
                        1 => return true,
                        -1 => return false,
                        _ => continue,
                    }
                }
                true // zero vector (cannot happen: filtered above)
            })
        })
        .ok_or_else(|| ScheduleError::NoValidPermutation(deps.clone()))?;

    // 2. λ^J.
    let mut lambda_j = vec![Poly::zero(np); n];
    let mut stride = Poly::constant(np, pi as i128);
    for &dim in &perm {
        lambda_j[dim] = stride.clone();
        let p_l = Poly::from_affine(&crate::polyhedral::AffineExpr::param(
            np, p_idx[dim],
        ));
        stride = stride.mul(&p_l);
    }

    // 3. λ^K candidates from tile-crossing variants.
    let mut lambda_k: Vec<Vec<Poly>> = vec![vec![Poly::zero(np)]; n];
    let mut extra = Vec::new();
    for st in &tiled.statements {
        let Some(gamma) = &st.gamma else { continue };
        if gamma.iter().all(|&g| g == 0) {
            continue; // intra-tile: causality via λ^J (permutation check)
        }
        // Skip crossings along unmapped dimensions (t_ℓ = 1): those
        // variants never execute.
        if gamma
            .iter()
            .enumerate()
            .any(|(l, &g)| g != 0 && tiled.mapping.t[l] == 1)
        {
            continue;
        }
        // required = π − λ^J·d_J
        let mut lj_dj = Poly::zero(np);
        for l in 0..n {
            lj_dj = lj_dj.add(&lambda_j[l].mul(&Poly::from_affine(&st.dj[l])));
        }
        let required = Poly::constant(np, pi as i128).sub(&lj_dj);
        let nonzero: Vec<usize> =
            (0..n).filter(|&l| st.dk[l] != 0).collect();
        match nonzero.as_slice() {
            [l] if st.dk[*l] == 1 => lambda_k[*l].push(required),
            _ => extra.push((st.dk.clone(), required)),
        }
    }

    let lc = critical_chain(&tiled.pra);
    Ok(Schedule { perm, pi, lambda_j, lambda_k, extra, lc })
}

/// All permutations of `0..n` in lexicographic order.
fn permutations(n: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut items: Vec<usize> = (0..n).collect();
    permute(&mut items, 0, &mut out);
    out.sort();
    out
}

fn permute(items: &mut Vec<usize>, start: usize, out: &mut Vec<Vec<usize>>) {
    if start == items.len() {
        out.push(items.clone());
        return;
    }
    for i in start..items.len() {
        items.swap(start, i);
        permute(items, start + 1, out);
        items.swap(start, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::{tile_pra, ArrayMapping};
    use crate::workloads::gesummv::gesummv;
    use crate::workloads::jacobi1d::jacobi1d_pra;

    #[test]
    fn example3_gesummv_schedule_vectors() {
        // Paper Example 3: λ^J = (1, p0), λ^K = (p0, p0(p1−1)+1) at π = 1.
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        assert_eq!(s.perm, vec![0, 1]);
        let params = [4i64, 5, 2, 3];
        assert_eq!(s.lambda_j_at(&params), vec![1, 2]); // (1, p0)
        // λ^K = (p0, p0(p1−1)+1) = (2, 2·2+1) = (2, 5)
        assert_eq!(s.lambda_k_at(&params), vec![2, 5]);
        assert_eq!(s.lc, 4); // paper: L_c = 4
        assert!(s.verify(&tiled, &params).is_empty());
    }

    #[test]
    fn gesummv_schedule_verifies_across_params() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        for n0 in 2..7 {
            for n1 in 2..7 {
                for p0 in 1..=n0 {
                    for p1 in 1..=n1 {
                        let params = [n0, n1, p0, p1];
                        assert!(
                            s.verify(&tiled, &params).is_empty(),
                            "violations at {params:?}: {:?}",
                            s.verify(&tiled, &params)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn jacobi_needs_space_fastest_order() {
        // The (1,−1) dependence rules out j0-fastest order: the scheduler
        // must pick perm = [1, 0] (space dimension fastest).
        let tiled = tile_pra(&jacobi1d_pra(), &ArrayMapping::new(vec![1, 4]));
        let s = find_schedule(&tiled, 1).unwrap();
        assert_eq!(s.perm, vec![1, 0]);
        for params in [[4i64, 8, 4, 2], [3, 9, 3, 3], [5, 12, 5, 3]] {
            let v = s.verify(&tiled, &params);
            assert!(v.is_empty(), "violations at {params:?}: {v:?}");
        }
    }

    #[test]
    fn pi_scales_lambda_j() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 3).unwrap();
        let params = [4i64, 5, 2, 3];
        assert_eq!(s.lambda_j_at(&params), vec![3, 6]); // π·(1, p0)
    }

    #[test]
    fn start_time_monotone_in_tile() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        let params = [4i64, 5, 2, 3];
        // Sequential: all start times inside a tile distinct.
        let mut seen = std::collections::BTreeSet::new();
        for j0 in 0..2 {
            for j1 in 0..3 {
                let t = s.start_time(&[j0, j1], &[0, 0], &params);
                assert!(seen.insert(t), "duplicate start time {t}");
            }
        }
    }

    #[test]
    fn all_workloads_schedulable() {
        for wl in crate::workloads::all() {
            for phase in &wl.phases {
                let nd = phase.ndims;
                let t = match nd {
                    2 => vec![2, 2],
                    3 => vec![2, 2, 1],
                    _ => vec![2; nd],
                };
                let tiled = tile_pra(phase, &ArrayMapping::new(t));
                let s = find_schedule(&tiled, 1);
                assert!(s.is_ok(), "{}: {:?}", phase.name, s.err());
            }
        }
    }
}
