//! Construction of the symbolic schedule vectors `(λ^J, λ^K)`.
//!
//! `λ^J` realizes a sequential lexicographic walk of the tile in a chosen
//! dimension permutation (fastest dimension first): `λ^J_{σ(m)} =
//! π·Π_{r<m} p_{σ(r)}`. The permutation must make every dependence vector
//! "mixed-radix positive" — its most significant non-zero component (in
//! σ-order) positive — which is exactly intra-tile causality
//! `λ^J·d ≥ 1` for `|d_ℓ| < p_ℓ`.
//!
//! `λ^K` is the component-wise least vector satisfying the inter-tile
//! causality constraints `λ^J·d_J + λ^K·d_K ≥ π` contributed by every
//! tile-crossing statement variant (cf. Example 3 of the paper, where
//! GESUMMV on a 2×2 array yields `λ^J = (1, p0)`,
//! `λ^K = (p0, p0(p1−1)+1)`). Entries are kept as *candidate lists* of
//! polynomials whose pointwise maximum is the schedule entry — the maximum
//! of polynomials is chamber-dependent, and deferring it keeps the
//! construction fully symbolic.

use crate::polyhedral::Poly;
use crate::tiling::TiledPra;

use super::latency::critical_chain;

/// A symbolic LSGP schedule.
///
/// One tiled mapping generally admits *several* feasible schedules — one
/// per causal dimension permutation — with genuinely different latency /
/// FD-pressure trade-offs. [`find_schedule`] returns the first (the
/// pre-enumeration behavior); [`enumerate_schedules`] yields them all.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Intra-tile dimension order, fastest first.
    pub perm: Vec<usize>,
    /// Initiation interval between consecutive intra-tile iterations.
    pub pi: i64,
    /// `λ^J` per dimension (monomials in the tile sizes).
    pub lambda_j: Vec<Poly>,
    /// `λ^K` per dimension as candidate lists; the entry is
    /// `max(0, max(candidates))` evaluated per parameter point.
    pub lambda_k: Vec<Vec<Poly>>,
    /// Causality constraints with multi-dimensional `d_K` (diagonal tile
    /// crossings): `(d_K, required)` meaning `λ^K·d_K ≥ required`.
    /// Checked by [`Schedule::verify`].
    pub extra: Vec<(Vec<i64>, Poly)>,
    /// Single-iteration latency `L_c = max_q(τ_q + w_q)` (Eq. 8).
    pub lc: i64,
}

/// Scheduling failure.
#[derive(Debug)]
pub enum ScheduleError {
    NoValidPermutation(Vec<Vec<i64>>),
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::NoValidPermutation(deps) => write!(
                f,
                "no lexicographic dimension order satisfies all intra-tile \
                 dependencies: {deps:?}"
            ),
        }
    }
}

impl std::error::Error for ScheduleError {}

impl Schedule {
    /// Evaluate `λ^J` at concrete parameters.
    ///
    /// Entries are `i128`: λ^J components are monomials in the tile
    /// sizes, so at the large symbolic parameters the paper's scalability
    /// claim is about (e.g. `p = 2³²` in a 3-deep nest) they exceed
    /// `i64` — the old lossy `as i64` truncation silently wrapped them.
    pub fn lambda_j_at(&self, params: &[i64]) -> Vec<i128> {
        self.lambda_j.iter().map(|p| p.eval(params)).collect()
    }

    /// Evaluate `λ^K` at concrete parameters (in `i128`, like
    /// [`Schedule::lambda_j_at`]).
    ///
    /// Per-dimension base values come from the symbolic candidate lists;
    /// the multi-dimensional (diagonal tile-crossing) constraints in
    /// [`Schedule::extra`] are then enforced by a small monotone fixpoint:
    /// whenever `λ^K·d_K < required`, the highest-indexed dimension with
    /// `d_K > 0` is bumped by the deficit. The fixpoint terminates because
    /// every bump strictly increases one component and requirements are
    /// finite; lexicographic positivity of the dependencies guarantees a
    /// positive component exists in every lower-bound constraint.
    ///
    /// Non-convergence within the round budget is detected on loop
    /// exit: a residual re-check of every enforceable constraint runs
    /// and fails a debug assertion if any is still violated. Release
    /// builds skip the assertion — there, callers that need the
    /// guarantee must run [`Schedule::verify`], which re-checks the
    /// full constraint system (including the pure-negative upper-bound
    /// rows this fixpoint deliberately leaves alone) in every build
    /// profile.
    pub fn lambda_k_at(&self, params: &[i64]) -> Vec<i128> {
        let mut lk: Vec<i128> = self
            .lambda_k
            .iter()
            .map(|cands| {
                cands
                    .iter()
                    .map(|c| c.eval(params))
                    .max()
                    .unwrap_or(0)
                    .max(0)
            })
            .collect();
        // Deficit of one *enforceable* constraint row (a row with some
        // positive `d_K` component); pure-negative rows are upper
        // bounds — checked by `verify`, not enforced (or counted as
        // divergence) here.
        fn enforceable_deficit(
            dk: &[i64],
            req: &Poly,
            lk: &[i128],
            params: &[i64],
        ) -> Option<i128> {
            let need = req.eval(params);
            let have: i128 =
                dk.iter().zip(lk).map(|(&d, l)| d as i128 * l).sum();
            (have < need && dk.iter().any(|&d| d > 0))
                .then_some(need - have)
        }
        let mut converged = self.extra.is_empty();
        for _round in 0..(4 * self.extra.len() + 4) {
            let mut changed = false;
            for (dk, req) in &self.extra {
                if let Some(deficit) =
                    enforceable_deficit(dk, req, &lk, params)
                {
                    let bump = (0..dk.len())
                        .rev()
                        .find(|&l| dk[l] > 0)
                        .expect("enforceable row has a positive component");
                    let d = dk[bump] as i128;
                    lk[bump] += (deficit + d - 1) / d;
                    changed = true;
                }
            }
            if !changed {
                converged = true;
                break;
            }
        }
        if !converged && cfg!(debug_assertions) {
            // Round budget exhausted with the last pass still bumping:
            // the fixpoint may not have settled. Re-check the residuals
            // instead of trusting the loop bound (debug builds only —
            // release callers get the always-on re-check via `verify`).
            let residual: Vec<String> = self
                .extra
                .iter()
                .filter_map(|(dk, req)| {
                    enforceable_deficit(dk, req, &lk, params).map(
                        |deficit| format!("λK·{dk:?} short by {deficit}"),
                    )
                })
                .collect();
            debug_assert!(
                residual.is_empty(),
                "λ^K fixpoint did not converge at {params:?}: \
                 {residual:?} (causality-violating schedule)"
            );
        }
        lk
    }

    /// Start time of iteration `(j, k)` (Eq. of §III-D:
    /// `t(j,k) = λ^J·j + λ^K·k`), in `i128` — schedule arithmetic never
    /// truncates, even at parameters where λ entries exceed `i64`.
    pub fn start_time(&self, j: &[i64], k: &[i64], params: &[i64]) -> i128 {
        let lj = self.lambda_j_at(params);
        let lk = self.lambda_k_at(params);
        lj.iter().zip(j).map(|(a, &b)| a * b as i128).sum::<i128>()
            + lk.iter().zip(k).map(|(a, &b)| a * b as i128).sum::<i128>()
    }

    /// Compact description of the intra-tile walk, fastest dimension
    /// first — e.g. `j0j1` for the natural order of a 2-deep nest,
    /// `j1j0` for the space-fastest order Jacobi needs. Distinct
    /// schedules of one mapping always carry distinct labels (they
    /// differ exactly in the permutation).
    pub fn perm_label(&self) -> String {
        self.perm.iter().map(|d| format!("j{d}")).collect()
    }

    /// Check every causality constraint at concrete parameters. Returns
    /// violated constraint descriptions (empty = schedule valid there).
    /// All arithmetic is `i128`, so a violation can never be masked by
    /// an intermediate overflow wrapping positive.
    ///
    /// This is a *point* check — valid exactly at `params`. An
    /// adversarial `λ^K` of too low a polynomial degree can pass it on
    /// every small grid yet violate causality at larger bounds; use
    /// [`Schedule::verify_symbolic`] to cover all parameters at once.
    pub fn verify(&self, tiled: &TiledPra, params: &[i64]) -> Vec<String> {
        let mut bad = Vec::new();
        let lj = self.lambda_j_at(params);
        let lk = self.lambda_k_at(params);
        for st in &tiled.statements {
            if st.gamma.is_none() {
                continue;
            }
            // Skip variants that never execute for this array size.
            let feasible = crate::polyhedral::count_concrete(
                &st.space,
                &tiled.mapping.t,
                params,
            ) > 0;
            if !feasible {
                continue;
            }
            let dj: i128 = st
                .dj
                .iter()
                .zip(&lj)
                .map(|(e, l)| e.eval(params) as i128 * l)
                .sum();
            let dk: i128 = st
                .dk
                .iter()
                .zip(&lk)
                .map(|(&d, l)| d as i128 * l)
                .sum();
            if dj + dk < self.pi as i128 {
                bad.push(format!(
                    "{}: λJ·dJ + λK·dK = {} < π = {} at {params:?}",
                    st.name,
                    dj + dk,
                    self.pi
                ));
            }
        }
        bad
    }

    /// All-parameter causality check — the symbolic analogue of
    /// [`Schedule::verify`], closing the gap that a point check only
    /// covers the parameters it is run at. Two tiers:
    ///
    /// 1. **Symbolic proof.** Each feasible tile-crossing row demands
    ///    `λ^J·d_J + λ^K·d_K ≥ π`. Since `λ^K_ℓ` is a pointwise max of
    ///    candidate polynomials, the row's slack is bounded by a ∃/∀
    ///    sweep over candidate selections: for dimensions with
    ///    `d_K[ℓ] > 0` any single candidate lower-bounds the max (one
    ///    passing selection suffices), while for `d_K[ℓ] < 0` the max is
    ///    attained by *some* candidate at every point (all selections
    ///    must pass). Each selected slack polynomial is certified
    ///    nonnegative over the analysis context chamber
    ///    ([`TiledPra::context`]: `p_ℓ ≥ max(1, max|d_ℓ|)`) by
    ///    substituting `p_ℓ = origin_ℓ + q_ℓ` and requiring every
    ///    coefficient of the shifted polynomial to be `≥ 0` — a
    ///    sufficient positivity certificate (see [`shifted_nonneg`]).
    /// 2. **Escalation ladder.** When the proof is inconclusive — a
    ///    diagonal tile crossing in [`Schedule::extra`] (its fixpoint
    ///    value has no closed form), a hand-built `λ^K`, or a genuinely
    ///    sign-mixed slack — fall back to [`Schedule::verify`] on an
    ///    exact-cover parameter grid with per-dimension tile sizes
    ///    `{max(2, dmax_ℓ), 8, 27}`. The geometric rungs separate
    ///    polynomial orders, so a `λ^K` entry of too low a degree (the
    ///    adversarial shape that fools small-grid point checks) fails by
    ///    the top rung.
    ///
    /// Returns violation descriptions like `verify`; empty means tier 1
    /// proved every row, or tier 2 found no violation on the ladder —
    /// weaker than a proof, but strictly stronger than any single-point
    /// `verify`, and rejection is always sound (a reported violation is
    /// a real one at the stated parameters).
    pub fn verify_symbolic(&self, tiled: &TiledPra) -> Vec<String> {
        if self.extra.is_empty() && self.rows_prove(tiled) {
            return Vec::new();
        }
        let n = tiled.pra.ndims;
        let dmax: Vec<i64> = (0..n)
            .map(|l| {
                tiled
                    .statements
                    .iter()
                    .map(|s| s.d[l].abs())
                    .max()
                    .unwrap_or(0)
                    .max(1)
            })
            .collect();
        self.ladder_verify(tiled, &dmax)
    }

    /// Tier 1 of [`Schedule::verify_symbolic`]: true iff every feasible
    /// tile-crossing row's slack carries a positivity certificate on the
    /// context chamber. A `false` is *inconclusive*, not a violation —
    /// the caller escalates to the sampling ladder.
    fn rows_prove(&self, tiled: &TiledPra) -> bool {
        let sp = &tiled.pra.space;
        let np = sp.len();
        let n = tiled.pra.ndims;
        let zero = Poly::zero(np);
        'rows: for st in &tiled.statements {
            let Some(gamma) = &st.gamma else { continue };
            // Crossings along unmapped dimensions (t_ℓ = 1) never
            // execute — the same filter the construction applies.
            if gamma
                .iter()
                .enumerate()
                .any(|(l, &g)| g != 0 && tiled.mapping.t[l] == 1)
            {
                continue;
            }
            // This variant's feasibility floor: the context chamber
            // gives `p_ℓ ≥ max(1, |d_ℓ|)`; a dimension the dependence
            // crosses *inside* the tile (`γ_ℓ = 0, d_ℓ ≠ 0`) further
            // needs `p_ℓ ≥ |d_ℓ| + 1` for both endpoints to fit, and
            // the variant's space is empty below that.
            let mut origin = vec![1i128; np];
            for l in 0..n {
                let d = st.d[l].unsigned_abs() as i128;
                origin[sp.p_index(l)] = if gamma[l] == 0 && st.d[l] != 0 {
                    d + 1
                } else {
                    d.max(1)
                };
            }
            // slack = λ^J·d_J − π + Σ_ℓ d_K[ℓ]·λ^K_ℓ
            let mut base = Poly::zero(np);
            for l in 0..n {
                self.lambda_j[l]
                    .mul_into(&Poly::from_affine(&st.dj[l]), &mut base);
            }
            base.sub_assign(&Poly::constant(np, self.pi as i128));
            let pos: Vec<usize> =
                (0..n).filter(|&l| st.dk[l] > 0).collect();
            let neg: Vec<usize> =
                (0..n).filter(|&l| st.dk[l] < 0).collect();
            // λ^K_ℓ = max(0, candidates): the zero polynomial is always
            // in the selection set.
            let sel = |l: usize| -> Vec<&Poly> {
                self.lambda_k[l]
                    .iter()
                    .chain(std::iter::once(&zero))
                    .collect()
            };
            let pos_sel: Vec<Vec<&Poly>> =
                pos.iter().map(|&l| sel(l)).collect();
            let neg_sel: Vec<Vec<&Poly>> =
                neg.iter().map(|&l| sel(l)).collect();
            let count =
                |s: &[Vec<&Poly>]| -> usize { s.iter().map(|v| v.len()).product() };
            if count(&pos_sel).saturating_mul(count(&neg_sel)) > 4096 {
                return false; // degenerate candidate blow-up: sample instead
            }
            let neg_combos = cartesian(&neg_sel);
            for pc in cartesian(&pos_sel) {
                let mut with_pos = base.clone();
                for (c, &l) in pc.iter().zip(&pos) {
                    with_pos.add_assign(&c.scale(st.dk[l] as i128));
                }
                let all_neg_ok = neg_combos.iter().all(|nc| {
                    let mut slack = with_pos.clone();
                    for (c, &l) in nc.iter().zip(&neg) {
                        slack.add_assign(&c.scale(st.dk[l] as i128));
                    }
                    shifted_nonneg(&slack, &origin)
                });
                if all_neg_ok {
                    continue 'rows;
                }
            }
            return false;
        }
        true
    }

    /// Tier 2 of [`Schedule::verify_symbolic`]: run the point check over
    /// an exact-cover grid (`N_ℓ = t_ℓ·p_ℓ`) whose per-dimension tile
    /// sizes grow geometrically past every small grid a point sweep
    /// would use.
    fn ladder_verify(&self, tiled: &TiledPra, dmax: &[i64]) -> Vec<String> {
        let sp = &tiled.pra.space;
        let np = sp.len();
        let n = tiled.pra.ndims;
        let rungs: Vec<Vec<i64>> = (0..n)
            .map(|l| {
                let mut v = vec![dmax[l].max(2), 8, 27];
                v.retain(|&x| x >= dmax[l]);
                v.sort_unstable();
                v.dedup();
                v
            })
            .collect();
        let mut bad = Vec::new();
        let mut idx = vec![0usize; n];
        loop {
            let p: Vec<i64> = (0..n).map(|l| rungs[l][idx[l]]).collect();
            let mut params = vec![0i64; np];
            for l in 0..n {
                params[sp.p_index(l)] = p[l];
                params[sp.n_index(l)] = p[l] * tiled.mapping.t[l];
            }
            for v in self.verify(tiled, &params) {
                bad.push(format!("[ladder p={p:?}] {v}"));
            }
            // Odometer over the rung grid; done when it wraps.
            let mut l = 0;
            loop {
                if l == n {
                    return bad;
                }
                idx[l] += 1;
                if idx[l] < rungs[l].len() {
                    break;
                }
                idx[l] = 0;
                l += 1;
            }
        }
    }
}

/// Positivity certificate: substitute `x_i = origin_i + q_i` and check
/// that every coefficient of the shifted polynomial is nonnegative —
/// then the polynomial is `≥ 0` wherever each parameter is at least its
/// origin. Sufficient, not necessary: a mixed-sign shifted form is
/// merely inconclusive (the caller falls back to sampling).
fn shifted_nonneg(poly: &Poly, origin: &[i128]) -> bool {
    let np = poly.nparams();
    let mut shifted = Poly::zero(np);
    for (expo, coeff) in poly.terms() {
        let mut term = Poly::constant(np, coeff);
        for (i, &e) in expo.iter().enumerate() {
            if e == 0 {
                continue;
            }
            let base = Poly::constant(np, origin[i]).add(
                &Poly::from_affine(&crate::polyhedral::AffineExpr::param(
                    np, i,
                )),
            );
            for _ in 0..e {
                term = term.mul(&base);
            }
        }
        shifted.add_assign(&term);
    }
    shifted.terms().all(|(_, c)| c >= 0)
}

/// All selections of one element per list (a single empty selection when
/// `lists` is empty) — the ∃/∀ sweep of `Schedule::rows_prove`.
fn cartesian<'a>(lists: &[Vec<&'a Poly>]) -> Vec<Vec<&'a Poly>> {
    let mut out = vec![Vec::new()];
    for list in lists {
        out = out
            .into_iter()
            .flat_map(|prefix| {
                list.iter().map(move |&c| {
                    let mut v = prefix.clone();
                    v.push(c);
                    v
                })
            })
            .collect();
    }
    out
}

/// All distinct non-zero original dependence vectors of a tiled PRA —
/// the constraint system every causal permutation must satisfy.
fn dependence_vectors(tiled: &TiledPra) -> Vec<Vec<i64>> {
    let mut deps: Vec<Vec<i64>> = tiled
        .statements
        .iter()
        .filter(|s| s.d.iter().any(|&x| x != 0))
        .map(|s| s.d.clone())
        .collect();
    deps.sort();
    deps.dedup();
    deps
}

/// Is `perm` (fastest dimension first) causal for every dependence —
/// i.e. is each vector "mixed-radix positive", its most significant
/// non-zero component (in σ-order) positive? This is exactly intra-tile
/// causality `λ^J·d ≥ 1` for `|d_ℓ| < p_ℓ`.
fn perm_is_causal(perm: &[usize], deps: &[Vec<i64>]) -> bool {
    deps.iter().all(|d| {
        // most significant non-zero (scanning slowest→fastest)
        for &dim in perm.iter().rev() {
            match d[dim].signum() {
                1 => return true,
                -1 => return false,
                _ => continue,
            }
        }
        true // zero vector (cannot happen: filtered by the caller)
    })
}

/// Build the schedule a given causal permutation induces: λ^J is forced
/// by (perm, π), and λ^K is the component-wise least solution of the
/// inter-tile causality constraints — so per permutation there is
/// exactly one non-dominated schedule, and enumerating permutations
/// enumerates the whole useful schedule space at fixed π.
fn schedule_for_perm(
    tiled: &TiledPra,
    pi: i64,
    perm: Vec<usize>,
) -> Schedule {
    let n = tiled.pra.ndims;
    let np = tiled.pra.space.len();
    let p_idx: Vec<usize> =
        (0..n).map(|l| tiled.pra.space.p_index(l)).collect();

    // λ^J: stride π·Π_{r<m} p_{σ(r)} along the permutation.
    let mut lambda_j = vec![Poly::zero(np); n];
    let mut stride = Poly::constant(np, pi as i128);
    for &dim in &perm {
        lambda_j[dim] = stride.clone();
        let p_l = Poly::from_affine(&crate::polyhedral::AffineExpr::param(
            np, p_idx[dim],
        ));
        stride = stride.mul(&p_l);
    }

    // λ^K candidates from tile-crossing variants.
    let mut lambda_k: Vec<Vec<Poly>> = vec![vec![Poly::zero(np)]; n];
    let mut extra = Vec::new();
    for st in &tiled.statements {
        let Some(gamma) = &st.gamma else { continue };
        if gamma.iter().all(|&g| g == 0) {
            continue; // intra-tile: causality via λ^J (permutation check)
        }
        // Skip crossings along unmapped dimensions (t_ℓ = 1): those
        // variants never execute.
        if gamma
            .iter()
            .enumerate()
            .any(|(l, &g)| g != 0 && tiled.mapping.t[l] == 1)
        {
            continue;
        }
        // required = π − λ^J·d_J  (accumulated in place: one growing
        // packed polynomial, no per-term temporaries)
        let mut lj_dj = Poly::zero(np);
        for l in 0..n {
            lambda_j[l].mul_into(&Poly::from_affine(&st.dj[l]), &mut lj_dj);
        }
        let required = Poly::constant(np, pi as i128).sub(&lj_dj);
        let nonzero: Vec<usize> =
            (0..n).filter(|&l| st.dk[l] != 0).collect();
        match nonzero.as_slice() {
            [l] if st.dk[*l] == 1 => lambda_k[*l].push(required),
            _ => extra.push((st.dk.clone(), required)),
        }
    }

    let lc = critical_chain(&tiled.pra);
    Schedule { perm, pi, lambda_j, lambda_k, extra, lc }
}

/// Enumerate every feasible symbolic schedule of a tiled PRA at
/// initiation interval `pi`, in deterministic order (lexicographic over
/// the dimension permutations), capped at `limit` candidates (`None` =
/// all). The first entry is always [`find_schedule`]'s pick; an empty
/// result means no causal lexicographic order exists.
///
/// Candidates differ in their dimension permutation and hence in
/// `(λ^J, λ^K)` — a latency / FD-pressure trade-off at identical energy,
/// which is what makes the schedule a design-space axis (see
/// `dse::DesignSpace::with_schedules`). The count is bounded by
/// `ndims!`, small for the loop depths PRAs have.
///
/// Soundness contract: the construction satisfies intra-tile causality
/// (the permutation filter) and every *enforceable* inter-tile row
/// (λ^K candidate lists + the [`Schedule::lambda_k_at`] fixpoint).
/// Pure-negative `d_K` rows — backward tile crossings — are upper
/// bounds that only [`Schedule::verify`] checks, exactly as for
/// [`find_schedule`]'s single pick. `tests/schedule_enum.rs` pins
/// verify-cleanliness for every candidate of every built-in workload;
/// callers enumerating *untrusted* PRAs should validate candidates with
/// [`Schedule::verify_symbolic`] — an all-parameter check, unlike the
/// per-point [`Schedule::verify`] — before trusting their latencies.
pub fn enumerate_schedules(
    tiled: &TiledPra,
    pi: i64,
    limit: Option<usize>,
) -> Vec<Schedule> {
    let cap = limit.unwrap_or(usize::MAX);
    let mut out = Vec::new();
    if cap == 0 {
        return out;
    }
    let deps = dependence_vectors(tiled);
    for perm in permutations(tiled.pra.ndims) {
        if perm_is_causal(&perm, &deps) {
            out.push(schedule_for_perm(tiled, pi, perm));
            if out.len() >= cap {
                break;
            }
        }
    }
    out
}

/// Find a symbolic schedule for a tiled PRA (π given; the paper's
/// experiments use π = 1): the first feasible candidate of
/// [`enumerate_schedules`] — natural dimension order preferred, which
/// reproduces the paper's λ^J for GESUMMV.
pub fn find_schedule(tiled: &TiledPra, pi: i64) -> Result<Schedule, ScheduleError> {
    enumerate_schedules(tiled, pi, Some(1))
        .into_iter()
        .next()
        .ok_or_else(|| {
            ScheduleError::NoValidPermutation(dependence_vectors(tiled))
        })
}

/// All permutations of `0..n` in lexicographic order.
fn permutations(n: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut items: Vec<usize> = (0..n).collect();
    permute(&mut items, 0, &mut out);
    out.sort();
    out
}

fn permute(items: &mut Vec<usize>, start: usize, out: &mut Vec<Vec<usize>>) {
    if start == items.len() {
        out.push(items.clone());
        return;
    }
    for i in start..items.len() {
        items.swap(start, i);
        permute(items, start + 1, out);
        items.swap(start, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::{tile_pra, ArrayMapping};
    use crate::workloads::gesummv::gesummv;
    use crate::workloads::jacobi1d::jacobi1d_pra;

    #[test]
    fn example3_gesummv_schedule_vectors() {
        // Paper Example 3: λ^J = (1, p0), λ^K = (p0, p0(p1−1)+1) at π = 1.
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        assert_eq!(s.perm, vec![0, 1]);
        let params = [4i64, 5, 2, 3];
        assert_eq!(s.lambda_j_at(&params), vec![1, 2]); // (1, p0)
        // λ^K = (p0, p0(p1−1)+1) = (2, 2·2+1) = (2, 5)
        assert_eq!(s.lambda_k_at(&params), vec![2, 5]);
        assert_eq!(s.lc, 4); // paper: L_c = 4
        assert!(s.verify(&tiled, &params).is_empty());
    }

    #[test]
    fn gesummv_schedule_verifies_across_params() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        for n0 in 2..7 {
            for n1 in 2..7 {
                for p0 in 1..=n0 {
                    for p1 in 1..=n1 {
                        let params = [n0, n1, p0, p1];
                        assert!(
                            s.verify(&tiled, &params).is_empty(),
                            "violations at {params:?}: {:?}",
                            s.verify(&tiled, &params)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn jacobi_needs_space_fastest_order() {
        // The (1,−1) dependence rules out j0-fastest order: the scheduler
        // must pick perm = [1, 0] (space dimension fastest).
        let tiled = tile_pra(&jacobi1d_pra(), &ArrayMapping::new(vec![1, 4]));
        let s = find_schedule(&tiled, 1).unwrap();
        assert_eq!(s.perm, vec![1, 0]);
        for params in [[4i64, 8, 4, 2], [3, 9, 3, 3], [5, 12, 5, 3]] {
            let v = s.verify(&tiled, &params);
            assert!(v.is_empty(), "violations at {params:?}: {v:?}");
        }
    }

    #[test]
    fn pi_scales_lambda_j() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 3).unwrap();
        let params = [4i64, 5, 2, 3];
        assert_eq!(s.lambda_j_at(&params), vec![3, 6]); // π·(1, p0)
    }

    #[test]
    fn start_time_monotone_in_tile() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        let params = [4i64, 5, 2, 3];
        // Sequential: all start times inside a tile distinct.
        let mut seen = std::collections::BTreeSet::new();
        for j0 in 0..2 {
            for j1 in 0..3 {
                let t = s.start_time(&[j0, j1], &[0, 0], &params);
                assert!(seen.insert(t), "duplicate start time {t}");
            }
        }
    }

    #[test]
    fn schedule_arithmetic_survives_symbolic_scale_parameters() {
        // Regression: the old path truncated `Poly::eval`'s i128 with
        // `as i64`. For GEMM's 3-deep nest at p = 2³², λ^J's last entry
        // is p0·p1 = 2⁶⁴, which wrapped to 0 — a silently causality-
        // violating schedule at exactly the parameter scales the paper's
        // scalability claim is about.
        use crate::workloads::gemm::gemm;
        let tiled = tile_pra(&gemm(), &ArrayMapping::new(vec![2, 2, 1]));
        let s = find_schedule(&tiled, 1).unwrap();
        let n = 1i64 << 32;
        let params = [n, n, n, n, n, n]; // (N0,N1,N2,p0,p1,p2)
        let lj = s.lambda_j_at(&params);
        assert!(lj.iter().all(|&x| x > 0), "λ^J wrapped: {lj:?}");
        assert_eq!(lj[s.perm[2]], 1i128 << 64, "λ^J = π·Π p exceeds i64");
        let lk = s.lambda_k_at(&params);
        assert!(lk.iter().all(|&x| x >= 0), "λ^K wrapped: {lk:?}");
        // The intra-tile span λ^J·(p−1) is ~2⁹⁶: start times stay exact.
        let j: Vec<i64> = vec![n - 1; 3];
        let t0 = s.start_time(&j, &[0, 0, 0], &params);
        assert!(t0 > i64::MAX as i128, "span must exceed i64: {t0}");

        // GESUMMV's λ^K_1 = p0(p1−1)+1 also exceeds i64 at p = 2³².
        let tiled2 = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s2 = find_schedule(&tiled2, 1).unwrap();
        let params2 = [n, n, n, n];
        let lk2 = s2.lambda_k_at(&params2);
        let p = n as i128;
        assert_eq!(lk2, vec![p, p * (p - 1) + 1]);
        assert!(lk2[1] > i64::MAX as i128);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "fixpoint did not converge")]
    fn lambda_k_fixpoint_divergence_is_detected() {
        // Two mutually-antagonistic diagonal constraints: every bump that
        // satisfies one deepens the other's deficit, so the bounded loop
        // can never settle. The residual re-check must refuse to return
        // the causality-violating λ^K silently.
        let np = 2;
        let s = Schedule {
            perm: vec![0, 1],
            pi: 1,
            lambda_j: vec![Poly::zero(np), Poly::zero(np)],
            lambda_k: vec![Vec::new(), Vec::new()],
            extra: vec![
                (vec![1, -2], Poly::constant(np, 10)),
                (vec![-2, 1], Poly::constant(np, 10)),
            ],
            lc: 1,
        };
        s.lambda_k_at(&[4, 4]);
    }

    #[test]
    fn enumeration_yields_all_causal_permutations_for_gesummv() {
        // GESUMMV's dependencies (1,0) and (0,1) are causal under either
        // dimension order: exactly two candidates, natural order first
        // (= find_schedule's pick), both passing verify.
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let all = enumerate_schedules(&tiled, 1, None);
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].perm, vec![0, 1]);
        assert_eq!(all[1].perm, vec![1, 0]);
        assert_eq!(all[0].perm_label(), "j0j1");
        assert_eq!(all[1].perm_label(), "j1j0");
        let first = find_schedule(&tiled, 1).unwrap();
        assert_eq!(all[0].perm, first.perm);
        let params = [4i64, 5, 2, 3];
        assert_eq!(all[0].lambda_j_at(&params), first.lambda_j_at(&params));
        assert_eq!(all[0].lambda_k_at(&params), first.lambda_k_at(&params));
        for s in &all {
            assert!(s.verify(&tiled, &params).is_empty(), "{:?}", s.perm);
        }
        // The two schedules genuinely differ: λ^J is permuted.
        assert_ne!(
            all[0].lambda_j_at(&params),
            all[1].lambda_j_at(&params)
        );
    }

    #[test]
    fn enumeration_excludes_non_causal_permutations() {
        // Jacobi's (1,−1) dependence rules out the j0-fastest order:
        // exactly one candidate survives.
        let tiled = tile_pra(&jacobi1d_pra(), &ArrayMapping::new(vec![1, 4]));
        let all = enumerate_schedules(&tiled, 1, None);
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].perm, vec![1, 0]);
    }

    #[test]
    fn enumeration_cap_and_determinism() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        assert!(enumerate_schedules(&tiled, 1, Some(0)).is_empty());
        assert_eq!(enumerate_schedules(&tiled, 1, Some(1)).len(), 1);
        // Deterministic: repeated enumeration yields the same order.
        let a: Vec<Vec<usize>> = enumerate_schedules(&tiled, 1, None)
            .into_iter()
            .map(|s| s.perm)
            .collect();
        let b: Vec<Vec<usize>> = enumerate_schedules(&tiled, 1, None)
            .into_iter()
            .map(|s| s.perm)
            .collect();
        assert_eq!(a, b);
    }

    #[test]
    fn adversarial_lambda_k_passes_point_checks_but_fails_symbolic() {
        // The untrusted-schedule gap: λ^K = (p0, 5p0−4) against the
        // correct (p0, p0(p1−1)+1). The impostor's second entry is
        // degree 1 where the true bound is degree 2, yet it dominates
        // wherever p0(6−p1) ≥ 5 — which contains every square grid a
        // small point sweep would try (p = (2,2), (3,3), (4,4) all
        // pass). Only a check that looks past fixed parameters can
        // reject it.
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let good = find_schedule(&tiled, 1).unwrap();
        let np = tiled.pra.space.len();
        let p0 = Poly::from_affine(&crate::polyhedral::AffineExpr::param(
            np,
            tiled.pra.space.p_index(0),
        ));
        let fake = Schedule {
            lambda_k: vec![
                vec![p0.clone()],
                vec![p0.scale(5).sub(&Poly::constant(np, 4))],
            ],
            ..good.clone()
        };
        // The point check is fooled at every small square grid...
        for params in [[4i64, 4, 2, 2], [6, 6, 3, 3], [8, 8, 4, 4]] {
            assert!(
                fake.verify(&tiled, &params).is_empty(),
                "point check unexpectedly rejected {params:?}"
            );
        }
        // ...but at p = (8,8): λ^K_1 = 36 < p0(p1−1)+1 = 57.
        assert!(!fake.verify(&tiled, &[16, 16, 8, 8]).is_empty());
        // The symbolic check rejects it without being told where to
        // look, and still accepts the genuine schedule.
        let bad = fake.verify_symbolic(&tiled);
        assert!(!bad.is_empty(), "adversarial λ^K accepted");
        assert!(bad.iter().any(|v| v.contains("[ladder")), "{bad:?}");
        assert!(good.verify_symbolic(&tiled).is_empty());
    }

    #[test]
    fn gesummv_schedule_is_proven_not_sampled() {
        // gesummv has no diagonal tile crossings (`extra` is empty), so
        // tier 1 alone must prove the schedule — without leaning on the
        // sampling ladder.
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        assert!(s.extra.is_empty());
        assert!(s.rows_prove(&tiled), "tier-1 certificate failed");
        assert!(s.verify_symbolic(&tiled).is_empty());
    }

    #[test]
    fn verify_symbolic_accepts_all_builtin_schedules() {
        // Every enumerated candidate of every built-in workload phase
        // passes the all-parameter check (by proof or by ladder).
        for wl in crate::workloads::all() {
            for phase in &wl.phases {
                let nd = phase.ndims;
                let t = match nd {
                    2 => vec![2, 2],
                    3 => vec![2, 2, 1],
                    _ => vec![2; nd],
                };
                let tiled = tile_pra(phase, &ArrayMapping::new(t));
                for s in enumerate_schedules(&tiled, 1, None) {
                    let bad = s.verify_symbolic(&tiled);
                    assert!(
                        bad.is_empty(),
                        "{} {}: {bad:?}",
                        phase.name,
                        s.perm_label()
                    );
                }
            }
        }
    }

    #[test]
    fn shifted_nonneg_certificate_is_sound_and_shifts_the_origin() {
        // p0·p1 − 1 at origin (1,1): shifted constant term is 0 — the
        // certificate accepts exactly because the region starts at 1.
        let np = 2;
        let p0 = Poly::from_affine(
            &crate::polyhedral::AffineExpr::param(np, 0),
        );
        let p1 = Poly::from_affine(
            &crate::polyhedral::AffineExpr::param(np, 1),
        );
        let prod_minus_1 = p0.mul(&p1).sub(&Poly::constant(np, 1));
        assert!(shifted_nonneg(&prod_minus_1, &[1, 1]));
        // p0 − 2 needs origin ≥ 2: inconclusive at 1, certified at 2.
        let m2 = p0.sub(&Poly::constant(np, 2));
        assert!(!shifted_nonneg(&m2, &[1, 1]));
        assert!(shifted_nonneg(&m2, &[2, 1]));
        // Genuinely negative polynomials never certify anywhere.
        let neg = Poly::constant(np, -1);
        assert!(!shifted_nonneg(&neg, &[5, 5]));
    }

    #[test]
    fn all_workloads_schedulable() {
        for wl in crate::workloads::all() {
            for phase in &wl.phases {
                let nd = phase.ndims;
                let t = match nd {
                    2 => vec![2, 2],
                    3 => vec![2, 2, 1],
                    _ => vec![2; nd],
                };
                let tiled = tile_pra(phase, &ArrayMapping::new(t));
                let s = find_schedule(&tiled, 1);
                assert!(s.is_ok(), "{}: {:?}", phase.name, s.err());
            }
        }
    }
}
