//! Construction of the symbolic schedule vectors `(λ^J, λ^K)`.
//!
//! `λ^J` realizes a sequential lexicographic walk of the tile in a chosen
//! dimension permutation (fastest dimension first): `λ^J_{σ(m)} =
//! π·Π_{r<m} p_{σ(r)}`. The permutation must make every dependence vector
//! "mixed-radix positive" — its most significant non-zero component (in
//! σ-order) positive — which is exactly intra-tile causality
//! `λ^J·d ≥ 1` for `|d_ℓ| < p_ℓ`.
//!
//! `λ^K` is the component-wise least vector satisfying the inter-tile
//! causality constraints `λ^J·d_J + λ^K·d_K ≥ π` contributed by every
//! tile-crossing statement variant (cf. Example 3 of the paper, where
//! GESUMMV on a 2×2 array yields `λ^J = (1, p0)`,
//! `λ^K = (p0, p0(p1−1)+1)`). Entries are kept as *candidate lists* of
//! polynomials whose pointwise maximum is the schedule entry — the maximum
//! of polynomials is chamber-dependent, and deferring it keeps the
//! construction fully symbolic.

use crate::polyhedral::Poly;
use crate::tiling::TiledPra;

use super::latency::critical_chain;

/// A symbolic LSGP schedule.
///
/// One tiled mapping generally admits *several* feasible schedules — one
/// per causal dimension permutation — with genuinely different latency /
/// FD-pressure trade-offs. [`find_schedule`] returns the first (the
/// pre-enumeration behavior); [`enumerate_schedules`] yields them all.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Intra-tile dimension order, fastest first.
    pub perm: Vec<usize>,
    /// Initiation interval between consecutive intra-tile iterations.
    pub pi: i64,
    /// `λ^J` per dimension (monomials in the tile sizes).
    pub lambda_j: Vec<Poly>,
    /// `λ^K` per dimension as candidate lists; the entry is
    /// `max(0, max(candidates))` evaluated per parameter point.
    pub lambda_k: Vec<Vec<Poly>>,
    /// Causality constraints with multi-dimensional `d_K` (diagonal tile
    /// crossings): `(d_K, required)` meaning `λ^K·d_K ≥ required`.
    /// Checked by [`Schedule::verify`].
    pub extra: Vec<(Vec<i64>, Poly)>,
    /// Single-iteration latency `L_c = max_q(τ_q + w_q)` (Eq. 8).
    pub lc: i64,
}

/// Scheduling failure.
#[derive(Debug)]
pub enum ScheduleError {
    NoValidPermutation(Vec<Vec<i64>>),
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::NoValidPermutation(deps) => write!(
                f,
                "no lexicographic dimension order satisfies all intra-tile \
                 dependencies: {deps:?}"
            ),
        }
    }
}

impl std::error::Error for ScheduleError {}

impl Schedule {
    /// Evaluate `λ^J` at concrete parameters.
    ///
    /// Entries are `i128`: λ^J components are monomials in the tile
    /// sizes, so at the large symbolic parameters the paper's scalability
    /// claim is about (e.g. `p = 2³²` in a 3-deep nest) they exceed
    /// `i64` — the old lossy `as i64` truncation silently wrapped them.
    pub fn lambda_j_at(&self, params: &[i64]) -> Vec<i128> {
        self.lambda_j.iter().map(|p| p.eval(params)).collect()
    }

    /// Evaluate `λ^K` at concrete parameters (in `i128`, like
    /// [`Schedule::lambda_j_at`]).
    ///
    /// Per-dimension base values come from the symbolic candidate lists;
    /// the multi-dimensional (diagonal tile-crossing) constraints in
    /// [`Schedule::extra`] are then enforced by a small monotone fixpoint:
    /// whenever `λ^K·d_K < required`, the highest-indexed dimension with
    /// `d_K > 0` is bumped by the deficit. The fixpoint terminates because
    /// every bump strictly increases one component and requirements are
    /// finite; lexicographic positivity of the dependencies guarantees a
    /// positive component exists in every lower-bound constraint.
    ///
    /// Non-convergence within the round budget is detected on loop
    /// exit: a residual re-check of every enforceable constraint runs
    /// and fails a debug assertion if any is still violated. Release
    /// builds skip the assertion — there, callers that need the
    /// guarantee must run [`Schedule::verify`], which re-checks the
    /// full constraint system (including the pure-negative upper-bound
    /// rows this fixpoint deliberately leaves alone) in every build
    /// profile.
    pub fn lambda_k_at(&self, params: &[i64]) -> Vec<i128> {
        let mut lk: Vec<i128> = self
            .lambda_k
            .iter()
            .map(|cands| {
                cands
                    .iter()
                    .map(|c| c.eval(params))
                    .max()
                    .unwrap_or(0)
                    .max(0)
            })
            .collect();
        // Deficit of one *enforceable* constraint row (a row with some
        // positive `d_K` component); pure-negative rows are upper
        // bounds — checked by `verify`, not enforced (or counted as
        // divergence) here.
        fn enforceable_deficit(
            dk: &[i64],
            req: &Poly,
            lk: &[i128],
            params: &[i64],
        ) -> Option<i128> {
            let need = req.eval(params);
            let have: i128 =
                dk.iter().zip(lk).map(|(&d, l)| d as i128 * l).sum();
            (have < need && dk.iter().any(|&d| d > 0))
                .then_some(need - have)
        }
        let mut converged = self.extra.is_empty();
        for _round in 0..(4 * self.extra.len() + 4) {
            let mut changed = false;
            for (dk, req) in &self.extra {
                if let Some(deficit) =
                    enforceable_deficit(dk, req, &lk, params)
                {
                    let bump = (0..dk.len())
                        .rev()
                        .find(|&l| dk[l] > 0)
                        .expect("enforceable row has a positive component");
                    let d = dk[bump] as i128;
                    lk[bump] += (deficit + d - 1) / d;
                    changed = true;
                }
            }
            if !changed {
                converged = true;
                break;
            }
        }
        if !converged && cfg!(debug_assertions) {
            // Round budget exhausted with the last pass still bumping:
            // the fixpoint may not have settled. Re-check the residuals
            // instead of trusting the loop bound (debug builds only —
            // release callers get the always-on re-check via `verify`).
            let residual: Vec<String> = self
                .extra
                .iter()
                .filter_map(|(dk, req)| {
                    enforceable_deficit(dk, req, &lk, params).map(
                        |deficit| format!("λK·{dk:?} short by {deficit}"),
                    )
                })
                .collect();
            debug_assert!(
                residual.is_empty(),
                "λ^K fixpoint did not converge at {params:?}: \
                 {residual:?} (causality-violating schedule)"
            );
        }
        lk
    }

    /// Start time of iteration `(j, k)` (Eq. of §III-D:
    /// `t(j,k) = λ^J·j + λ^K·k`), in `i128` — schedule arithmetic never
    /// truncates, even at parameters where λ entries exceed `i64`.
    pub fn start_time(&self, j: &[i64], k: &[i64], params: &[i64]) -> i128 {
        let lj = self.lambda_j_at(params);
        let lk = self.lambda_k_at(params);
        lj.iter().zip(j).map(|(a, &b)| a * b as i128).sum::<i128>()
            + lk.iter().zip(k).map(|(a, &b)| a * b as i128).sum::<i128>()
    }

    /// Compact description of the intra-tile walk, fastest dimension
    /// first — e.g. `j0j1` for the natural order of a 2-deep nest,
    /// `j1j0` for the space-fastest order Jacobi needs. Distinct
    /// schedules of one mapping always carry distinct labels (they
    /// differ exactly in the permutation).
    pub fn perm_label(&self) -> String {
        self.perm.iter().map(|d| format!("j{d}")).collect()
    }

    /// Check every causality constraint at concrete parameters. Returns
    /// violated constraint descriptions (empty = schedule valid there).
    /// All arithmetic is `i128`, so a violation can never be masked by
    /// an intermediate overflow wrapping positive.
    pub fn verify(&self, tiled: &TiledPra, params: &[i64]) -> Vec<String> {
        let mut bad = Vec::new();
        let lj = self.lambda_j_at(params);
        let lk = self.lambda_k_at(params);
        for st in &tiled.statements {
            if st.gamma.is_none() {
                continue;
            }
            // Skip variants that never execute for this array size.
            let feasible = crate::polyhedral::count_concrete(
                &st.space,
                &tiled.mapping.t,
                params,
            ) > 0;
            if !feasible {
                continue;
            }
            let dj: i128 = st
                .dj
                .iter()
                .zip(&lj)
                .map(|(e, l)| e.eval(params) as i128 * l)
                .sum();
            let dk: i128 = st
                .dk
                .iter()
                .zip(&lk)
                .map(|(&d, l)| d as i128 * l)
                .sum();
            if dj + dk < self.pi as i128 {
                bad.push(format!(
                    "{}: λJ·dJ + λK·dK = {} < π = {} at {params:?}",
                    st.name,
                    dj + dk,
                    self.pi
                ));
            }
        }
        bad
    }
}

/// All distinct non-zero original dependence vectors of a tiled PRA —
/// the constraint system every causal permutation must satisfy.
fn dependence_vectors(tiled: &TiledPra) -> Vec<Vec<i64>> {
    let mut deps: Vec<Vec<i64>> = tiled
        .statements
        .iter()
        .filter(|s| s.d.iter().any(|&x| x != 0))
        .map(|s| s.d.clone())
        .collect();
    deps.sort();
    deps.dedup();
    deps
}

/// Is `perm` (fastest dimension first) causal for every dependence —
/// i.e. is each vector "mixed-radix positive", its most significant
/// non-zero component (in σ-order) positive? This is exactly intra-tile
/// causality `λ^J·d ≥ 1` for `|d_ℓ| < p_ℓ`.
fn perm_is_causal(perm: &[usize], deps: &[Vec<i64>]) -> bool {
    deps.iter().all(|d| {
        // most significant non-zero (scanning slowest→fastest)
        for &dim in perm.iter().rev() {
            match d[dim].signum() {
                1 => return true,
                -1 => return false,
                _ => continue,
            }
        }
        true // zero vector (cannot happen: filtered by the caller)
    })
}

/// Build the schedule a given causal permutation induces: λ^J is forced
/// by (perm, π), and λ^K is the component-wise least solution of the
/// inter-tile causality constraints — so per permutation there is
/// exactly one non-dominated schedule, and enumerating permutations
/// enumerates the whole useful schedule space at fixed π.
fn schedule_for_perm(
    tiled: &TiledPra,
    pi: i64,
    perm: Vec<usize>,
) -> Schedule {
    let n = tiled.pra.ndims;
    let np = tiled.pra.space.len();
    let p_idx: Vec<usize> =
        (0..n).map(|l| tiled.pra.space.p_index(l)).collect();

    // λ^J: stride π·Π_{r<m} p_{σ(r)} along the permutation.
    let mut lambda_j = vec![Poly::zero(np); n];
    let mut stride = Poly::constant(np, pi as i128);
    for &dim in &perm {
        lambda_j[dim] = stride.clone();
        let p_l = Poly::from_affine(&crate::polyhedral::AffineExpr::param(
            np, p_idx[dim],
        ));
        stride = stride.mul(&p_l);
    }

    // λ^K candidates from tile-crossing variants.
    let mut lambda_k: Vec<Vec<Poly>> = vec![vec![Poly::zero(np)]; n];
    let mut extra = Vec::new();
    for st in &tiled.statements {
        let Some(gamma) = &st.gamma else { continue };
        if gamma.iter().all(|&g| g == 0) {
            continue; // intra-tile: causality via λ^J (permutation check)
        }
        // Skip crossings along unmapped dimensions (t_ℓ = 1): those
        // variants never execute.
        if gamma
            .iter()
            .enumerate()
            .any(|(l, &g)| g != 0 && tiled.mapping.t[l] == 1)
        {
            continue;
        }
        // required = π − λ^J·d_J  (accumulated in place: one growing
        // packed polynomial, no per-term temporaries)
        let mut lj_dj = Poly::zero(np);
        for l in 0..n {
            lambda_j[l].mul_into(&Poly::from_affine(&st.dj[l]), &mut lj_dj);
        }
        let required = Poly::constant(np, pi as i128).sub(&lj_dj);
        let nonzero: Vec<usize> =
            (0..n).filter(|&l| st.dk[l] != 0).collect();
        match nonzero.as_slice() {
            [l] if st.dk[*l] == 1 => lambda_k[*l].push(required),
            _ => extra.push((st.dk.clone(), required)),
        }
    }

    let lc = critical_chain(&tiled.pra);
    Schedule { perm, pi, lambda_j, lambda_k, extra, lc }
}

/// Enumerate every feasible symbolic schedule of a tiled PRA at
/// initiation interval `pi`, in deterministic order (lexicographic over
/// the dimension permutations), capped at `limit` candidates (`None` =
/// all). The first entry is always [`find_schedule`]'s pick; an empty
/// result means no causal lexicographic order exists.
///
/// Candidates differ in their dimension permutation and hence in
/// `(λ^J, λ^K)` — a latency / FD-pressure trade-off at identical energy,
/// which is what makes the schedule a design-space axis (see
/// `dse::DesignSpace::with_schedules`). The count is bounded by
/// `ndims!`, small for the loop depths PRAs have.
///
/// Soundness contract: the construction satisfies intra-tile causality
/// (the permutation filter) and every *enforceable* inter-tile row
/// (λ^K candidate lists + the [`Schedule::lambda_k_at`] fixpoint).
/// Pure-negative `d_K` rows — backward tile crossings — are upper
/// bounds that only [`Schedule::verify`] checks, exactly as for
/// [`find_schedule`]'s single pick. `tests/schedule_enum.rs` pins
/// verify-cleanliness for every candidate of every built-in workload;
/// callers enumerating *untrusted* PRAs should spot-check candidates
/// with [`Schedule::verify`] at representative parameters before
/// trusting their latencies.
pub fn enumerate_schedules(
    tiled: &TiledPra,
    pi: i64,
    limit: Option<usize>,
) -> Vec<Schedule> {
    let cap = limit.unwrap_or(usize::MAX);
    let mut out = Vec::new();
    if cap == 0 {
        return out;
    }
    let deps = dependence_vectors(tiled);
    for perm in permutations(tiled.pra.ndims) {
        if perm_is_causal(&perm, &deps) {
            out.push(schedule_for_perm(tiled, pi, perm));
            if out.len() >= cap {
                break;
            }
        }
    }
    out
}

/// Find a symbolic schedule for a tiled PRA (π given; the paper's
/// experiments use π = 1): the first feasible candidate of
/// [`enumerate_schedules`] — natural dimension order preferred, which
/// reproduces the paper's λ^J for GESUMMV.
pub fn find_schedule(tiled: &TiledPra, pi: i64) -> Result<Schedule, ScheduleError> {
    enumerate_schedules(tiled, pi, Some(1))
        .into_iter()
        .next()
        .ok_or_else(|| {
            ScheduleError::NoValidPermutation(dependence_vectors(tiled))
        })
}

/// All permutations of `0..n` in lexicographic order.
fn permutations(n: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut items: Vec<usize> = (0..n).collect();
    permute(&mut items, 0, &mut out);
    out.sort();
    out
}

fn permute(items: &mut Vec<usize>, start: usize, out: &mut Vec<Vec<usize>>) {
    if start == items.len() {
        out.push(items.clone());
        return;
    }
    for i in start..items.len() {
        items.swap(start, i);
        permute(items, start + 1, out);
        items.swap(start, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::{tile_pra, ArrayMapping};
    use crate::workloads::gesummv::gesummv;
    use crate::workloads::jacobi1d::jacobi1d_pra;

    #[test]
    fn example3_gesummv_schedule_vectors() {
        // Paper Example 3: λ^J = (1, p0), λ^K = (p0, p0(p1−1)+1) at π = 1.
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        assert_eq!(s.perm, vec![0, 1]);
        let params = [4i64, 5, 2, 3];
        assert_eq!(s.lambda_j_at(&params), vec![1, 2]); // (1, p0)
        // λ^K = (p0, p0(p1−1)+1) = (2, 2·2+1) = (2, 5)
        assert_eq!(s.lambda_k_at(&params), vec![2, 5]);
        assert_eq!(s.lc, 4); // paper: L_c = 4
        assert!(s.verify(&tiled, &params).is_empty());
    }

    #[test]
    fn gesummv_schedule_verifies_across_params() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        for n0 in 2..7 {
            for n1 in 2..7 {
                for p0 in 1..=n0 {
                    for p1 in 1..=n1 {
                        let params = [n0, n1, p0, p1];
                        assert!(
                            s.verify(&tiled, &params).is_empty(),
                            "violations at {params:?}: {:?}",
                            s.verify(&tiled, &params)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn jacobi_needs_space_fastest_order() {
        // The (1,−1) dependence rules out j0-fastest order: the scheduler
        // must pick perm = [1, 0] (space dimension fastest).
        let tiled = tile_pra(&jacobi1d_pra(), &ArrayMapping::new(vec![1, 4]));
        let s = find_schedule(&tiled, 1).unwrap();
        assert_eq!(s.perm, vec![1, 0]);
        for params in [[4i64, 8, 4, 2], [3, 9, 3, 3], [5, 12, 5, 3]] {
            let v = s.verify(&tiled, &params);
            assert!(v.is_empty(), "violations at {params:?}: {v:?}");
        }
    }

    #[test]
    fn pi_scales_lambda_j() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 3).unwrap();
        let params = [4i64, 5, 2, 3];
        assert_eq!(s.lambda_j_at(&params), vec![3, 6]); // π·(1, p0)
    }

    #[test]
    fn start_time_monotone_in_tile() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s = find_schedule(&tiled, 1).unwrap();
        let params = [4i64, 5, 2, 3];
        // Sequential: all start times inside a tile distinct.
        let mut seen = std::collections::BTreeSet::new();
        for j0 in 0..2 {
            for j1 in 0..3 {
                let t = s.start_time(&[j0, j1], &[0, 0], &params);
                assert!(seen.insert(t), "duplicate start time {t}");
            }
        }
    }

    #[test]
    fn schedule_arithmetic_survives_symbolic_scale_parameters() {
        // Regression: the old path truncated `Poly::eval`'s i128 with
        // `as i64`. For GEMM's 3-deep nest at p = 2³², λ^J's last entry
        // is p0·p1 = 2⁶⁴, which wrapped to 0 — a silently causality-
        // violating schedule at exactly the parameter scales the paper's
        // scalability claim is about.
        use crate::workloads::gemm::gemm;
        let tiled = tile_pra(&gemm(), &ArrayMapping::new(vec![2, 2, 1]));
        let s = find_schedule(&tiled, 1).unwrap();
        let n = 1i64 << 32;
        let params = [n, n, n, n, n, n]; // (N0,N1,N2,p0,p1,p2)
        let lj = s.lambda_j_at(&params);
        assert!(lj.iter().all(|&x| x > 0), "λ^J wrapped: {lj:?}");
        assert_eq!(lj[s.perm[2]], 1i128 << 64, "λ^J = π·Π p exceeds i64");
        let lk = s.lambda_k_at(&params);
        assert!(lk.iter().all(|&x| x >= 0), "λ^K wrapped: {lk:?}");
        // The intra-tile span λ^J·(p−1) is ~2⁹⁶: start times stay exact.
        let j: Vec<i64> = vec![n - 1; 3];
        let t0 = s.start_time(&j, &[0, 0, 0], &params);
        assert!(t0 > i64::MAX as i128, "span must exceed i64: {t0}");

        // GESUMMV's λ^K_1 = p0(p1−1)+1 also exceeds i64 at p = 2³².
        let tiled2 = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let s2 = find_schedule(&tiled2, 1).unwrap();
        let params2 = [n, n, n, n];
        let lk2 = s2.lambda_k_at(&params2);
        let p = n as i128;
        assert_eq!(lk2, vec![p, p * (p - 1) + 1]);
        assert!(lk2[1] > i64::MAX as i128);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "fixpoint did not converge")]
    fn lambda_k_fixpoint_divergence_is_detected() {
        // Two mutually-antagonistic diagonal constraints: every bump that
        // satisfies one deepens the other's deficit, so the bounded loop
        // can never settle. The residual re-check must refuse to return
        // the causality-violating λ^K silently.
        let np = 2;
        let s = Schedule {
            perm: vec![0, 1],
            pi: 1,
            lambda_j: vec![Poly::zero(np), Poly::zero(np)],
            lambda_k: vec![Vec::new(), Vec::new()],
            extra: vec![
                (vec![1, -2], Poly::constant(np, 10)),
                (vec![-2, 1], Poly::constant(np, 10)),
            ],
            lc: 1,
        };
        s.lambda_k_at(&[4, 4]);
    }

    #[test]
    fn enumeration_yields_all_causal_permutations_for_gesummv() {
        // GESUMMV's dependencies (1,0) and (0,1) are causal under either
        // dimension order: exactly two candidates, natural order first
        // (= find_schedule's pick), both passing verify.
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        let all = enumerate_schedules(&tiled, 1, None);
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].perm, vec![0, 1]);
        assert_eq!(all[1].perm, vec![1, 0]);
        assert_eq!(all[0].perm_label(), "j0j1");
        assert_eq!(all[1].perm_label(), "j1j0");
        let first = find_schedule(&tiled, 1).unwrap();
        assert_eq!(all[0].perm, first.perm);
        let params = [4i64, 5, 2, 3];
        assert_eq!(all[0].lambda_j_at(&params), first.lambda_j_at(&params));
        assert_eq!(all[0].lambda_k_at(&params), first.lambda_k_at(&params));
        for s in &all {
            assert!(s.verify(&tiled, &params).is_empty(), "{:?}", s.perm);
        }
        // The two schedules genuinely differ: λ^J is permuted.
        assert_ne!(
            all[0].lambda_j_at(&params),
            all[1].lambda_j_at(&params)
        );
    }

    #[test]
    fn enumeration_excludes_non_causal_permutations() {
        // Jacobi's (1,−1) dependence rules out the j0-fastest order:
        // exactly one candidate survives.
        let tiled = tile_pra(&jacobi1d_pra(), &ArrayMapping::new(vec![1, 4]));
        let all = enumerate_schedules(&tiled, 1, None);
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].perm, vec![1, 0]);
    }

    #[test]
    fn enumeration_cap_and_determinism() {
        let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
        assert!(enumerate_schedules(&tiled, 1, Some(0)).is_empty());
        assert_eq!(enumerate_schedules(&tiled, 1, Some(1)).len(), 1);
        // Deterministic: repeated enumeration yields the same order.
        let a: Vec<Vec<usize>> = enumerate_schedules(&tiled, 1, None)
            .into_iter()
            .map(|s| s.perm)
            .collect();
        let b: Vec<Vec<usize>> = enumerate_schedules(&tiled, 1, None)
            .into_iter()
            .map(|s| s.perm)
            .collect();
        assert_eq!(a, b);
    }

    #[test]
    fn all_workloads_schedulable() {
        for wl in crate::workloads::all() {
            for phase in &wl.phases {
                let nd = phase.ndims;
                let t = match nd {
                    2 => vec![2, 2],
                    3 => vec![2, 2, 1],
                    _ => vec![2; nd],
                };
                let tiled = tile_pra(phase, &ArrayMapping::new(t));
                let s = find_schedule(&tiled, 1);
                assert!(s.is_ok(), "{}: {:?}", phase.name, s.err());
            }
        }
    }
}
