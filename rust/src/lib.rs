//! # tcpa-energy
//!
//! Symbolic polyhedral-based energy analysis for nested loop programs
//! mapped and scheduled on processor array accelerators (TCPAs).
//!
//! Reproduction of: Nirmala, Walter, Hannig, Teich, *"Symbolic
//! Polyhedral-Based Energy Analysis for Nested Loop Programs"*, CS.AR 2026.
//!
//! The crate is organised bottom-up:
//!
//! * [`polyhedral`] — parametric affine expressions, piecewise
//!   quasi-polynomials, integer sets and both exact (enumeration) and
//!   symbolic (parametric) lattice-point counting. This is the in-repo
//!   substitute for ISL/Barvinok.
//! * [`pra`] — Piecewise Linear/Regular Algorithm IR: iteration spaces,
//!   quantified statements, dependence vectors, variable classification and
//!   the reduced dependence graph (RDG).
//! * [`lint`] — multi-pass static verification over the PRA IR and an
//!   optional array mapping: structural well-formedness, symbolic
//!   Fourier–Motzkin proofs (bounds safety, dependence coverage,
//!   reachability) and mapping/schedule hazards, with stable lint codes
//!   and a machine-readable report. `analyze`/`dse` preflight through it.
//! * [`workloads`] — PolyBench kernels expressed as PRAs plus functional
//!   semantics used by the simulator and the golden-model check.
//! * [`workloads::text`] — the textual workload frontend behind
//!   `--workload-file`: a dependency-free lexer/parser/lowering pipeline
//!   for a PolyBench-style loop-nest format (`examples/workloads/*.wl`),
//!   with line/column diagnostics and a renderer whose round-trip is
//!   fingerprint-exact.
//! * [`tiling`] — symbolic LSGP tiling (Eq. 3–7 of the paper).
//! * [`schedule`] — symbolic intra/inter-tile schedule vectors and the
//!   latency formula (Eq. 8).
//! * [`energy`] — the per-access energy table (Table I), the access-location
//!   classification `L(x)` and the per-statement energy (Eq. 9/10).
//! * [`analysis`] — the paper's contribution: the end-to-end symbolic energy
//!   analysis producing a piecewise quasi-polynomial `E_tot(N, p)` (Eq. 11).
//! * [`dse`] — design-space exploration: multi-axis spaces (array shapes,
//!   tile scales, energy policies, bounds grids), a parallel channel-fed
//!   explorer with cooperative cancellation and checkpoint/resume
//!   journals, a memoizing analysis cache, and multi-objective Pareto
//!   frontier / knee-point selection.
//! * [`cancel`] — cooperative cancellation tokens (SIGINT, wall-clock
//!   deadlines, programmatic) honored between design points and inside
//!   the Fourier–Motzkin loops.
//! * [`sim`] — cycle-accurate TCPA simulator (the paper's baseline):
//!   PE array, register files, interconnect, I/O buffers, DMA, counters.
//! * [`runtime`] — PJRT runtime loading AOT-compiled JAX/Pallas artifacts
//!   (the L2/L1 golden numeric model) from `artifacts/*.hlo.txt`;
//!   feature-gated (`pjrt`), with a dependency-free stub by default.
//! * [`coordinator`] — CLI driver, validation and legacy DSE shim.
//! * [`report`] — CSV / markdown / ASCII-figure emitters for the paper's
//!   tables, figures, and DSE frontiers.
//!
//! ## Where the paper lives in the code
//!
//! | paper | code |
//! |-------|------|
//! | Table I (45 nm access energies) | [`energy::table`], routed per architecture by [`energy::backend`] |
//! | Eq. 8 (global latency) | [`mod@schedule::latency`] |
//! | §IV (symbolic lattice-point counting, Eq. 12/13) | [`polyhedral`] |
//! | §V evaluation flow (Eq. 11 → exploration) | [`analysis`] → [`dse`] |
//! | §V-A validation oracles | [`sim`] + [`coordinator::validate`] |
//! | §III-B well-formedness side conditions (proved, not sampled) | [`lint`] (`tcpa-energy lint`) |
//!
//! The prose version of this map — with the data-flow diagram and the
//! caching story — is [`architecture`] (docs/ARCHITECTURE.md in the
//! repository); the quickstart and CLI tour are [`readme`] (README.md).

pub mod polyhedral;
pub mod pra;
pub mod lint;
pub mod workloads;
pub mod tiling;
pub mod schedule;
pub mod energy;
pub mod analysis;
pub mod cancel;
pub mod dse;
pub mod sim;
pub mod runtime;
pub mod coordinator;
pub mod report;
pub mod proptest_lite;
pub mod bench_util;

/// The repository README, embedded so its quickstart example compiles
/// as a doc test (`cargo test --doc`) and the rendered docs carry the
/// CLI tour.
#[doc = include_str!("../../README.md")]
pub mod readme {}

/// The paper-structure → code guide (docs/ARCHITECTURE.md), embedded so
/// its examples compile as doc tests and the map cannot silently drift
/// from the code it describes.
#[doc = include_str!("../../docs/ARCHITECTURE.md")]
pub mod architecture {}
