//! Multi-pass static analysis ("lint") over the PRA IR and, optionally,
//! an array mapping — the front gate of the pipeline: `analyze` and `dse`
//! refuse workloads with deny-level findings before any tiling, counting,
//! or simulation runs (see `tcpa-energy lint` and the `--no-lint` escape
//! hatch in [`crate::coordinator::cli`]).
//!
//! Three passes, each one file, registered in [`PASSES`]:
//!
//! * **structural** ([`structural`]) — shape-level well-formedness,
//!   absorbing [`crate::pra::validate`] (duplicate names, arities,
//!   dependence/condition/access-function dimensions, undefined reads,
//!   zero-dependence cycles) and extending it with dataflow hygiene
//!   (reduction shape, unused iteration dimensions, dead tensors, dead
//!   statements).
//! * **polyhedral** ([`polyhedral`]) — *symbolic proofs* via
//!   Fourier–Motzkin over the combined iteration+parameter space:
//!   bounds-safety of every tensor access for **all** parameter values
//!   (emptiness of the violation polyhedron), dependence coverage
//!   (every read `v[i − d]` lands on some producer of `v`), and
//!   guard satisfiability (unreachable-statement warnings). No grid
//!   sampling anywhere — see [`polyhedral::FmCtx`].
//! * **mapping** ([`mapping`]) — hazards of a concrete array mapping:
//!   schedule causality ([`crate::schedule::Schedule::verify_symbolic`]),
//!   write-write conflicts (two statements assigning one variable at a
//!   jointly feasible iteration point execute in the same cycle on the
//!   same PE), and out-of-budget feed-forward register pressure. Runs
//!   only when [`LintOptions::array`] is set.
//!
//! Lint codes are stable: `L0xx` structural, `L1xx` polyhedral, `L2xx`
//! mapping/schedule. Adding a lint means adding a [`LintCode`] variant
//! and emitting it from (or adding) a pass file — the registry, report,
//! JSON, and CLI pick it up unchanged.

use crate::pra::{Pra, Workload};

pub mod mapping;
pub mod polyhedral;
pub mod structural;

/// How bad a finding is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Suspicious but not disqualifying; printed, never fatal unless
    /// `--deny warnings`.
    Warn,
    /// The workload (or mapping) is wrong: `analyze`/`dse` refuse it.
    Deny,
}

impl Severity {
    /// Lowercase label used in reports and JSON.
    pub fn label(&self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

/// Stable lint codes. `L0xx` structural, `L1xx` polyhedral, `L2xx`
/// mapping/schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LintCode {
    /// Duplicate statement name.
    L001,
    /// Operator arity mismatch.
    L002,
    /// Tensor access function malformed (rank / row width / offset
    /// length).
    L003,
    /// Dependence or condition coefficient vector has the wrong length.
    L004,
    /// Read of an undefined variable or undeclared tensor.
    L005,
    /// Dependence structure unexecutable: non-lex-positive dependence
    /// vector or zero-dependence cycle.
    L006,
    /// Malformed reduction: a statement folds two or more reads of its
    /// own left-hand variable.
    L007,
    /// Iteration dimension unused by every access, dependence, and
    /// condition.
    L008,
    /// Declared tensor never read or written.
    L009,
    /// Statement defines a variable no statement reads.
    L010,
    /// Tensor access provably out of bounds for some admissible
    /// parameters.
    L100,
    /// Uncovered dependence: a read `v[i − d]` can land where no
    /// producer of `v` is active (or outside the iteration space).
    L101,
    /// Unreachable statement: its guard is infeasible for every
    /// admissible parameter value.
    L102,
    /// Acausal schedule: no feasible schedule exists for the mapping,
    /// or the symbolic causality check rejects it.
    L200,
    /// Write-write conflict: two statements assign one variable at a
    /// jointly feasible iteration point (same cycle, same PE).
    L201,
    /// Feed-forward register pressure exceeds the FD budget.
    L202,
}

impl LintCode {
    /// Every code, in report order.
    pub const ALL: [LintCode; 16] = [
        LintCode::L001,
        LintCode::L002,
        LintCode::L003,
        LintCode::L004,
        LintCode::L005,
        LintCode::L006,
        LintCode::L007,
        LintCode::L008,
        LintCode::L009,
        LintCode::L010,
        LintCode::L100,
        LintCode::L101,
        LintCode::L102,
        LintCode::L200,
        LintCode::L201,
        LintCode::L202,
    ];

    /// Stable textual code, e.g. `"L100"`.
    pub fn as_str(&self) -> &'static str {
        match self {
            LintCode::L001 => "L001",
            LintCode::L002 => "L002",
            LintCode::L003 => "L003",
            LintCode::L004 => "L004",
            LintCode::L005 => "L005",
            LintCode::L006 => "L006",
            LintCode::L007 => "L007",
            LintCode::L008 => "L008",
            LintCode::L009 => "L009",
            LintCode::L010 => "L010",
            LintCode::L100 => "L100",
            LintCode::L101 => "L101",
            LintCode::L102 => "L102",
            LintCode::L200 => "L200",
            LintCode::L201 => "L201",
            LintCode::L202 => "L202",
        }
    }

    /// Short human title.
    pub fn title(&self) -> &'static str {
        match self {
            LintCode::L001 => "duplicate statement name",
            LintCode::L002 => "operator arity mismatch",
            LintCode::L003 => "malformed tensor access function",
            LintCode::L004 => "dependence/condition vector length",
            LintCode::L005 => "undefined variable or tensor",
            LintCode::L006 => "unexecutable dependence structure",
            LintCode::L007 => "malformed reduction",
            LintCode::L008 => "unused iteration dimension",
            LintCode::L009 => "dead tensor",
            LintCode::L010 => "dead statement",
            LintCode::L100 => "out-of-bounds tensor access",
            LintCode::L101 => "uncovered dependence",
            LintCode::L102 => "unreachable statement",
            LintCode::L200 => "acausal schedule",
            LintCode::L201 => "write-write conflict",
            LintCode::L202 => "FD register pressure over budget",
        }
    }

    /// Severity of this code.
    pub fn severity(&self) -> Severity {
        match self {
            LintCode::L008
            | LintCode::L009
            | LintCode::L010
            | LintCode::L102
            | LintCode::L202 => Severity::Warn,
            _ => Severity::Deny,
        }
    }
}

impl std::fmt::Display for LintCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Finding {
    pub code: LintCode,
    /// Statement the finding anchors to, when there is one.
    pub statement: Option<String>,
    pub message: String,
}

impl Finding {
    pub(crate) fn new(
        code: LintCode,
        statement: Option<&str>,
        message: String,
    ) -> Self {
        Finding { code, statement: statement.map(str::to_string), message }
    }
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} [{}] {}",
            self.code,
            self.code.severity().label(),
            self.code.title()
        )?;
        if let Some(s) = &self.statement {
            write!(f, " ({s})")?;
        }
        write!(f, ": {}", self.message)
    }
}

/// Lint configuration.
#[derive(Debug, Clone)]
pub struct LintOptions {
    /// Array shape `t`. `Some` enables the mapping pass; the shape is
    /// padded with trailing `1`s to each phase's loop depth, exactly as
    /// the analyze/dse paths pad theirs.
    pub array: Option<Vec<i64>>,
    /// Initiation interval for the schedule pass.
    pub pi: i64,
    /// Feed-forward register budget (default: the simulator's
    /// [`crate::sim::ArchConfig`] FD size).
    pub fd_budget: usize,
}

impl Default for LintOptions {
    fn default() -> Self {
        LintOptions {
            array: None,
            pi: 1,
            fd_budget: crate::sim::RegFileSizes::default().fd,
        }
    }
}

/// Outcome of one pass over one PRA.
#[derive(Debug, Clone)]
pub struct PassOutcome {
    pub name: &'static str,
    /// `false` when the pass was skipped (no mapping given, or
    /// structural findings made later passes unsafe to run).
    pub ran: bool,
    pub findings: usize,
}

/// One registered pass. New lints are one file each: write the pass
/// function, add a row here.
struct Pass {
    name: &'static str,
    /// Needs [`LintOptions::array`].
    needs_mapping: bool,
    run: fn(&Pra, &LintOptions, &mut Vec<Finding>),
}

/// The pass registry, in execution order.
const PASSES: [Pass; 3] = [
    Pass { name: "structural", needs_mapping: false, run: structural::run },
    Pass { name: "polyhedral", needs_mapping: false, run: polyhedral::run },
    Pass { name: "mapping", needs_mapping: true, run: mapping::run },
];

/// Structural codes whose presence makes later passes unsafe (their
/// shape invariants — vector lengths, declared tensors — no longer
/// hold, so polyhedral/mapping analysis could index out of range).
fn blocks_later_passes(code: LintCode) -> bool {
    matches!(
        code,
        LintCode::L002 | LintCode::L003 | LintCode::L004 | LintCode::L005
    )
}

/// Lint report for one PRA: findings plus which passes ran.
#[derive(Debug, Clone)]
pub struct LintReport {
    /// PRA (phase) name.
    pub pra: String,
    pub findings: Vec<Finding>,
    pub passes: Vec<PassOutcome>,
}

impl LintReport {
    /// Any deny-level finding?
    pub fn has_deny(&self) -> bool {
        self.findings.iter().any(|f| f.code.severity() == Severity::Deny)
    }

    /// Number of deny-level findings.
    pub fn deny_count(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.code.severity() == Severity::Deny)
            .count()
    }

    /// Number of warn-level findings.
    pub fn warn_count(&self) -> usize {
        self.findings.len() - self.deny_count()
    }

    /// Clean under the given policy (`deny_warnings` promotes warnings).
    pub fn is_clean(&self, deny_warnings: bool) -> bool {
        if deny_warnings {
            self.findings.is_empty()
        } else {
            !self.has_deny()
        }
    }

    /// Human-readable report.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "lint {}: {} deny, {} warn",
            self.pra,
            self.deny_count(),
            self.warn_count()
        );
        for p in &self.passes {
            let _ = writeln!(
                out,
                "  pass {:10} {}",
                p.name,
                if p.ran {
                    format!("{} finding(s)", p.findings)
                } else {
                    "skipped".to_string()
                }
            );
        }
        for f in &self.findings {
            let _ = writeln!(out, "  {f}");
        }
        out
    }

    /// Machine-readable JSON (hand-rolled like every artifact emitter in
    /// this vendor-free tree).
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\"pra\":\"{}\",\"deny\":{},\"warn\":{},\"passes\":[",
            json_escape(&self.pra),
            self.deny_count(),
            self.warn_count()
        );
        for (i, p) in self.passes.iter().enumerate() {
            let _ = write!(
                out,
                "{}{{\"name\":\"{}\",\"ran\":{},\"findings\":{}}}",
                if i > 0 { "," } else { "" },
                p.name,
                p.ran,
                p.findings
            );
        }
        let _ = write!(out, "],\"findings\":[");
        for (i, f) in self.findings.iter().enumerate() {
            let _ = write!(
                out,
                "{}{{\"code\":\"{}\",\"severity\":\"{}\",\
                 \"statement\":{},\"message\":\"{}\"}}",
                if i > 0 { "," } else { "" },
                f.code,
                f.code.severity().label(),
                match &f.statement {
                    Some(s) => format!("\"{}\"", json_escape(s)),
                    None => "null".to_string(),
                },
                json_escape(&f.message)
            );
        }
        let _ = write!(out, "]}}");
        out
    }
}

/// Escape a string for embedding in a JSON literal.
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

/// Run every applicable pass over one PRA.
pub fn lint_pra(pra: &Pra, opts: &LintOptions) -> LintReport {
    let mut findings = Vec::new();
    let mut passes = Vec::new();
    let mut blocked = false;
    for pass in &PASSES {
        let skip = (pass.needs_mapping && opts.array.is_none())
            || (blocked && pass.name != "structural");
        if skip {
            passes.push(PassOutcome { name: pass.name, ran: false, findings: 0 });
            continue;
        }
        let before = findings.len();
        (pass.run)(pra, opts, &mut findings);
        passes.push(PassOutcome {
            name: pass.name,
            ran: true,
            findings: findings.len() - before,
        });
        if findings[before..].iter().any(|f| blocks_later_passes(f.code)) {
            blocked = true;
        }
    }
    // Deterministic order regardless of pass internals.
    findings.sort_by(|a, b| {
        (a.code, &a.statement, &a.message).cmp(&(
            b.code,
            &b.statement,
            &b.message,
        ))
    });
    LintReport { pra: pra.name.clone(), findings, passes }
}

/// Lint every phase of a workload (one report per phase).
pub fn lint_workload(wl: &Workload, opts: &LintOptions) -> Vec<LintReport> {
    wl.phases.iter().map(|p| lint_pra(p, opts)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_table_is_consistent() {
        for c in LintCode::ALL {
            assert_eq!(format!("{c}"), c.as_str());
            assert!(!c.title().is_empty());
        }
        assert_eq!(LintCode::L100.severity(), Severity::Deny);
        assert_eq!(LintCode::L102.severity(), Severity::Warn);
        assert!(Severity::Warn < Severity::Deny);
    }

    #[test]
    fn builtin_workloads_lint_clean_without_mapping() {
        let opts = LintOptions::default();
        for wl in crate::workloads::all() {
            for rep in lint_workload(&wl, &opts) {
                assert!(
                    rep.findings.is_empty(),
                    "{}: {}",
                    rep.pra,
                    rep.render()
                );
                // Without a mapping the first two passes run, the
                // mapping pass is recorded skipped.
                assert!(rep.passes[0].ran && rep.passes[1].ran);
                assert!(!rep.passes[2].ran);
            }
        }
    }

    #[test]
    fn json_report_is_well_formed() {
        let wl = crate::workloads::by_name("gesummv").unwrap();
        let rep = lint_pra(&wl.phases[0], &LintOptions::default());
        let j = rep.to_json();
        assert!(j.starts_with("{\"pra\":\"gesummv\""), "{j}");
        assert!(j.contains("\"deny\":0"));
        assert!(j.contains("\"passes\":["));
        assert!(j.ends_with("]}"));
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }
}
