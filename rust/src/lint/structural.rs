//! Structural pass: shape-level well-formedness (`L001`–`L010`).
//!
//! The deny half absorbs [`crate::pra::validate`] — every [`PraError`]
//! maps onto a stable lint code, so the panic-helper
//! [`crate::pra::assert_valid`] (trusted construction paths) and this
//! pass (untrusted input) report the same defects. The warn half adds
//! dataflow hygiene the validator never had: malformed reductions,
//! unused iteration dimensions, dead tensors, dead statements.

use crate::pra::{Lhs, Operand, Pra, PraError};

use super::{Finding, LintCode, LintOptions};

/// Map a validator error onto its lint code.
fn code_of(e: &PraError) -> LintCode {
    match e {
        PraError::DuplicateName(..) => LintCode::L001,
        PraError::Arity(..) => LintCode::L002,
        PraError::AccessRank(..)
        | PraError::AccessDims(..)
        | PraError::AccessOffset(..) => LintCode::L003,
        PraError::DepLen(..) | PraError::CondLen(..) => LintCode::L004,
        PraError::UndefinedVar(..) | PraError::UnknownTensor(..) => {
            LintCode::L005
        }
        PraError::ZeroDepCycle | PraError::NonLexPositiveDep(..) => {
            LintCode::L006
        }
    }
}

/// Statement a validator error anchors to, when it names one.
fn statement_of(e: &PraError) -> Option<&str> {
    match e {
        PraError::Arity(s, ..)
        | PraError::DepLen(s, ..)
        | PraError::UnknownTensor(s, ..)
        | PraError::UndefinedVar(s, ..)
        | PraError::CondLen(s, ..)
        | PraError::NonLexPositiveDep(s, ..)
        | PraError::DuplicateName(s)
        | PraError::AccessRank(s, ..)
        | PraError::AccessDims(s, ..)
        | PraError::AccessOffset(s, ..) => Some(s),
        PraError::ZeroDepCycle => None,
    }
}

pub(super) fn run(pra: &Pra, _opts: &LintOptions, out: &mut Vec<Finding>) {
    let errs = crate::pra::validate(pra);
    let mut shapes_ok = true;
    for e in &errs {
        let code = code_of(e);
        if super::blocks_later_passes(code) {
            shapes_ok = false;
        }
        out.push(Finding::new(code, statement_of(e), e.to_string()));
    }
    // The hygiene warns index into dependence vectors, access rows and
    // condition coefficients — only safe once the shape checks passed.
    if !shapes_ok {
        return;
    }
    reduction_shape(pra, out);
    unused_dims(pra, out);
    dead_tensors(pra, out);
    dead_statements(pra, out);
}

/// `L007`: a reduction folds exactly one previous value of its own
/// variable; two or more self-reads in one statement cannot be realized
/// as a single-assignment accumulation chain. (A zero-dependence
/// self-read is already `L006` via the zero-dependence cycle check.)
fn reduction_shape(pra: &Pra, out: &mut Vec<Finding>) {
    for s in &pra.statements {
        let Lhs::Var(lhs) = &s.lhs else { continue };
        let self_reads = s
            .args
            .iter()
            .filter(
                |a| matches!(a, Operand::Var { name, .. } if name == lhs),
            )
            .count();
        if self_reads >= 2 {
            out.push(Finding::new(
                LintCode::L007,
                Some(&s.name),
                format!(
                    "statement folds {self_reads} reads of its own \
                     variable {lhs}; a single-assignment reduction may \
                     fold at most one"
                ),
            ));
        }
    }
}

/// `L008`: an iteration dimension no access function, dependence vector,
/// or condition mentions — the loop only replicates work.
fn unused_dims(pra: &Pra, out: &mut Vec<Finding>) {
    for l in 0..pra.ndims {
        let map_uses =
            |m: &crate::pra::IndexMap| m.rows.iter().any(|r| r[l] != 0);
        let used = pra.statements.iter().any(|s| {
            s.args.iter().any(|a| match a {
                Operand::Var { dep, .. } => dep[l] != 0,
                Operand::Tensor { map, .. } => map_uses(map),
            }) || matches!(&s.lhs, Lhs::Tensor { map, .. } if map_uses(map))
                || s.cond.iter().any(|c| c.a[l] != 0)
        });
        if !used {
            out.push(Finding::new(
                LintCode::L008,
                None,
                format!(
                    "iteration dimension i{l} is unused by every access, \
                     dependence, and condition"
                ),
            ));
        }
    }
}

/// `L009`: a declared tensor nothing reads or writes.
fn dead_tensors(pra: &Pra, out: &mut Vec<Finding>) {
    for t in &pra.tensors {
        let used = pra.statements.iter().any(|s| {
            s.args.iter().any(
                |a| matches!(a, Operand::Tensor { name, .. } if *name == t.name),
            ) || matches!(&s.lhs, Lhs::Tensor { name, .. } if *name == t.name)
        });
        if !used {
            out.push(Finding::new(
                LintCode::L009,
                None,
                format!("tensor {} is declared but never accessed", t.name),
            ));
        }
    }
}

/// `L010`: a statement defining a variable no statement reads (tensor
/// writes are outputs and never dead). Statements whose variable is read
/// only by themselves (a self-sustaining propagation nothing consumes)
/// are dead too.
fn dead_statements(pra: &Pra, out: &mut Vec<Finding>) {
    for s in &pra.statements {
        let Lhs::Var(v) = &s.lhs else { continue };
        let read_elsewhere = pra.statements.iter().any(|c| {
            c.name != s.name
                && c.args.iter().any(
                    |a| matches!(a, Operand::Var { name, .. } if name == v),
                )
        });
        if !read_elsewhere {
            out.push(Finding::new(
                LintCode::L010,
                Some(&s.name),
                format!("defines {v}, which no other statement reads"),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::ParamSpace;
    use crate::pra::{IndexMap, Op, Statement, TensorDecl, TensorDim};

    fn base(nd: usize) -> Pra {
        Pra {
            name: "t".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![],
            tensors: vec![],
            requires: vec![],
        }
    }

    fn lint(pra: &Pra) -> Vec<Finding> {
        let mut out = Vec::new();
        run(pra, &LintOptions::default(), &mut out);
        out
    }

    #[test]
    fn validator_errors_get_codes() {
        let mut pra = base(1);
        pra.statements.push(Statement {
            name: "S1".into(),
            lhs: Lhs::Var("a".into()),
            op: Op::Add, // arity 2, one arg → L002
            args: vec![Operand::var0("ghost", 1)], // undefined → L005
            cond: vec![],
        });
        let f = lint(&pra);
        assert!(f.iter().any(|x| x.code == LintCode::L002), "{f:?}");
        assert!(f.iter().any(|x| x.code == LintCode::L005), "{f:?}");
        // Shape errors present → hygiene warns suppressed.
        assert!(f.iter().all(|x| x.code != LintCode::L010));
    }

    #[test]
    fn double_self_read_is_l007() {
        let mut pra = base(1);
        pra.statements.push(Statement {
            name: "S1".into(),
            lhs: Lhs::Var("a".into()),
            op: Op::Add,
            args: vec![
                Operand::var("a", vec![1]),
                Operand::var("a", vec![1]),
            ],
            cond: vec![],
        });
        // Consume `a` so L010 does not fire alongside.
        pra.statements.push(Statement {
            name: "S2".into(),
            lhs: Lhs::Tensor {
                name: "T".into(),
                map: IndexMap::identity(1, 1),
            },
            op: Op::Copy,
            args: vec![Operand::var0("a", 1)],
            cond: vec![],
        });
        pra.tensors.push(TensorDecl {
            name: "T".into(),
            shape: vec![TensorDim::Param(0)],
        });
        let f = lint(&pra);
        assert_eq!(
            f.iter().filter(|x| x.code == LintCode::L007).count(),
            1,
            "{f:?}"
        );
    }

    #[test]
    fn hygiene_warns_fire() {
        let mut pra = base(2);
        // S1 defines a variable nobody reads (L010), uses only i0
        // (i1 unused → L008); tensor D declared, never touched (L009).
        pra.statements.push(Statement {
            name: "S1".into(),
            lhs: Lhs::Var("a".into()),
            op: Op::Copy,
            args: vec![Operand::tensor("T", IndexMap::select(&[0], 2))],
            cond: vec![],
        });
        pra.tensors.push(TensorDecl {
            name: "T".into(),
            shape: vec![TensorDim::Param(0)],
        });
        pra.tensors.push(TensorDecl {
            name: "D".into(),
            shape: vec![TensorDim::Param(0)],
        });
        let f = lint(&pra);
        for code in [LintCode::L008, LintCode::L009, LintCode::L010] {
            assert!(f.iter().any(|x| x.code == code), "{code}: {f:?}");
        }
    }
}
