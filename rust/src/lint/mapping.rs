//! Mapping pass: hazards of one PRA under a concrete array mapping
//! (`L200`–`L202`). Skipped unless the lint invocation names an array
//! shape ([`LintOptions::array`]); the other two passes are
//! mapping-independent.
//!
//! * **`L200` causality** — [`crate::schedule::find_schedule`] must find
//!   a feasible schedule vector for the tiled PRA at the given `π`, and
//!   [`crate::schedule::Schedule::verify_symbolic`] must certify it (the
//!   positivity-certificate / escalation-ladder proof, not a point
//!   check).
//! * **`L201` write–write conflicts** — two statements writing the same
//!   destination on overlapping iterations execute in the same cycle on
//!   the same PE; the overlap check is the same Fourier–Motzkin
//!   emptiness proof the polyhedral pass uses, under the context
//!   `N_ℓ ≥ 2` (single-trip dimensions collapse every boundary case
//!   onto one point; a PRA that genuinely needs `N_ℓ = 1` should say so
//!   via `requires`).
//! * **`L202` FD pressure** — the static FIFO-depth formula the
//!   simulator enforces at run time
//!   (`Σ max(0, ⌊d·λ^J/π⌋)` over all carried reads), evaluated on the
//!   exact-cover rungs `N_ℓ = t_ℓ·{2, 8}`, against
//!   [`LintOptions::fd_budget`].
//!
//! The pass assumes the PRA's parameter space is the standard
//! `loop_nest` layout (`N0.. , p0..`), which is what the tiling
//! transform itself requires.

use crate::pra::{Lhs, Operand, Pra};
use crate::schedule::find_schedule;
use crate::tiling::{pad_array, tile_pra, ArrayMapping};

use super::polyhedral::FmCtx;
use super::{Finding, LintCode, LintOptions};

pub(super) fn run(pra: &Pra, opts: &LintOptions, out: &mut Vec<Finding>) {
    let Some(array) = &opts.array else { return };
    let t = pad_array(array, pra.ndims);
    let label = t
        .iter()
        .map(|x| x.to_string())
        .collect::<Vec<_>>()
        .join("x");
    let mapping = ArrayMapping::new(t);
    let tiled = tile_pra(pra, &mapping);

    let schedule = match find_schedule(&tiled, opts.pi) {
        Err(e) => {
            out.push(Finding::new(
                LintCode::L200,
                None,
                format!("array {label}, π = {}: {e}", opts.pi),
            ));
            None
        }
        Ok(s) => {
            let fails = s.verify_symbolic(&tiled);
            if fails.is_empty() {
                Some(s)
            } else {
                out.push(Finding::new(
                    LintCode::L200,
                    None,
                    format!(
                        "array {label}, schedule {}: symbolic causality \
                         verification failed: {}",
                        s.perm_label(),
                        fails.join("; ")
                    ),
                ));
                None
            }
        }
    };

    write_write_conflicts(pra, &label, out);

    if let Some(schedule) = &schedule {
        fd_pressure(pra, opts, &mapping, schedule, &label, out);
    }
}

/// `L201`: two writers of one destination on overlapping iterations.
fn write_write_conflicts(pra: &Pra, label: &str, out: &mut Vec<Finding>) {
    let ctx = FmCtx::new(pra);
    let base = ctx.context(2);
    let zero = vec![0i64; pra.ndims];
    let space = ctx.in_space(&zero);
    for (i, s1) in pra.statements.iter().enumerate() {
        for s2 in &pra.statements[i + 1..] {
            let same_dest = match (&s1.lhs, &s2.lhs) {
                (Lhs::Var(a), Lhs::Var(b)) => a == b,
                (
                    Lhs::Tensor { name: a, map: ma },
                    Lhs::Tensor { name: b, map: mb },
                ) => a == b && ma == mb,
                _ => false,
            };
            if !same_dest {
                continue;
            }
            let c1 = ctx.conds(s1, &zero);
            let c2 = ctx.conds(s2, &zero);
            if ctx.feasible(&[&c1, &c2, &space, &base]) {
                out.push(Finding::new(
                    LintCode::L201,
                    Some(&s1.name),
                    format!(
                        "statements {} and {} both write {} on \
                         overlapping iterations — same cycle, same PE \
                         under array {label}",
                        s1.name,
                        s2.name,
                        s1.lhs.name(),
                    ),
                ));
            }
        }
    }
}

/// `L202`: the simulator's static FIFO-depth formula, checked on the
/// exact-cover ladder before any simulation runs.
fn fd_pressure(
    pra: &Pra,
    opts: &LintOptions,
    mapping: &ArrayMapping,
    schedule: &crate::schedule::Schedule,
    label: &str,
    out: &mut Vec<Finding>,
) {
    for rung in [2i64, 8] {
        let bounds: Vec<i64> =
            mapping.t.iter().map(|&tl| tl * rung).collect();
        let params = mapping.params_for(&bounds);
        let lj = schedule.lambda_j_at(&params);
        let mut fd = 0i128;
        for s in &pra.statements {
            for arg in &s.args {
                let Operand::Var { dep, .. } = arg else { continue };
                if dep.iter().all(|&d| d == 0) {
                    continue;
                }
                let dist: i128 = dep
                    .iter()
                    .zip(&lj)
                    .map(|(&d, &l)| d as i128 * l)
                    .sum::<i128>()
                    / i128::from(opts.pi.max(1));
                fd += dist.max(0);
            }
        }
        if fd > opts.fd_budget as i128 {
            out.push(Finding::new(
                LintCode::L202,
                None,
                format!(
                    "array {label}: FD pressure {fd} exceeds the \
                     register budget {} at tile size {rung} (bounds \
                     {bounds:?}, schedule {})",
                    opts.fd_budget,
                    schedule.perm_label(),
                ),
            ));
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::ParamSpace;
    use crate::pra::{
        CondConstraint, IndexMap, Op, Statement, TensorDecl, TensorDim,
    };

    fn opts(array: &[i64]) -> LintOptions {
        LintOptions { array: Some(array.to_vec()), ..Default::default() }
    }

    fn lint(pra: &Pra, o: &LintOptions) -> Vec<Finding> {
        let mut out = Vec::new();
        run(pra, o, &mut out);
        out
    }

    #[test]
    fn skipped_without_array() {
        let wl = crate::workloads::by_name("gemm").unwrap();
        let f = lint(&wl.phases[0], &LintOptions::default());
        assert!(f.is_empty());
    }

    #[test]
    fn builtins_map_clean_of_deny_findings() {
        // Deny-clean, not warning-free: the `L202` FD ladder legitimately
        // warns on deep kernels at large tile sizes (the validator works
        // around the same pressure by widening `regs.fd` before it
        // simulates) — that is a capacity advisory, not a defect.
        for wl in crate::workloads::all() {
            for phase in &wl.phases {
                let shape: Vec<i64> = match phase.ndims {
                    2 => vec![2, 2],
                    3 => vec![2, 2, 1],
                    n => vec![2; n],
                };
                let f = lint(phase, &opts(&shape));
                assert!(
                    f.iter().all(|x| x.code.severity()
                        != crate::lint::Severity::Deny),
                    "{} / {}: {f:?}",
                    wl.name,
                    phase.name
                );
            }
        }
    }

    #[test]
    fn acausal_pra_is_l200() {
        let wl = crate::workloads::twist_unschedulable();
        let f = lint(&wl.phases[0], &opts(&[2, 2]));
        assert!(
            f.iter().any(|x| x.code == LintCode::L200),
            "{f:?}"
        );
    }

    #[test]
    fn overlapping_writers_are_l201() {
        // Two unconditional writers of the same variable.
        let nd = 1;
        let mk = |name: &str| Statement {
            name: name.into(),
            lhs: Lhs::Var("a".into()),
            op: Op::Copy,
            args: vec![Operand::tensor("T", IndexMap::identity(1, nd))],
            cond: vec![],
        };
        let pra = Pra {
            name: "ww".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![mk("S1"), mk("S2")],
            tensors: vec![TensorDecl {
                name: "T".into(),
                shape: vec![TensorDim::Param(0)],
            }],
            requires: vec![],
        };
        let f = lint(&pra, &opts(&[2]));
        assert!(
            f.iter().any(|x| x.code == LintCode::L201),
            "{f:?}"
        );
    }

    #[test]
    fn disjoint_writers_are_clean() {
        // The propagate idiom: writer at i0 = 0, writer at i0 ≥ 1.
        let nd = 1;
        let np = 2;
        let pra = Pra {
            name: "prop".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![
                Statement {
                    name: "S1".into(),
                    lhs: Lhs::Var("a".into()),
                    op: Op::Copy,
                    args: vec![Operand::tensor(
                        "T",
                        IndexMap::identity(1, nd),
                    )],
                    cond: vec![
                        CondConstraint::ge_const(0, 0, nd, np),
                        CondConstraint::le_const(0, 0, nd, np),
                    ],
                },
                Statement {
                    name: "S2".into(),
                    lhs: Lhs::Var("a".into()),
                    op: Op::Copy,
                    args: vec![Operand::var("a", vec![1])],
                    cond: vec![CondConstraint::ge_const(0, 1, nd, np)],
                },
            ],
            tensors: vec![TensorDecl {
                name: "T".into(),
                shape: vec![TensorDim::Param(0)],
            }],
            requires: vec![],
        };
        let f = lint(&pra, &opts(&[2]));
        assert!(
            f.iter().all(|x| x.code != LintCode::L201),
            "{f:?}"
        );
    }

    #[test]
    fn tiny_fd_budget_is_l202() {
        let wl = crate::workloads::by_name("gemm").unwrap();
        let o = LintOptions {
            array: Some(vec![8, 8]),
            fd_budget: 0,
            ..Default::default()
        };
        let f = lint(&wl.phases[0], &o);
        assert!(
            f.iter().any(|x| x.code == LintCode::L202),
            "{f:?}"
        );
    }
}
