//! Polyhedral pass: symbolic proofs over the combined iteration +
//! parameter space (`L100`–`L102`).
//!
//! Every obligation is phrased as the *emptiness of a violation
//! polyhedron* and discharged by Fourier–Motzkin elimination
//! ([`crate::polyhedral::guard`]'s `fm_feasible`) with the iteration
//! variables `i0..` prepended to the PRA's parameter space — so one
//! elimination covers **all** parameter values at once; nothing here
//! samples a bounds grid. FM decides *rational* feasibility: a
//! "feasible" answer may lack integer points, which errs toward
//! reporting (safe for deny lints), while "infeasible" is a proof of
//! integer emptiness (what `L102` needs before calling a statement
//! unreachable).
//!
//! The obligations:
//!
//! * **`L100` bounds safety** — for each tensor access row `m_r(i)` with
//!   declared extent `e_r(N)`, the sets
//!   `{cond ∧ i ∈ space ∧ requires ∧ m_r(i) < 0}` and
//!   `{… ∧ m_r(i) ≥ e_r}` must both be empty.
//! * **`L101` dependence coverage** — a read `v[i − d]` must land inside
//!   the iteration space *and* inside some producer's condition space.
//!   The complement of the producers' union is expanded piecewise: one
//!   negated condition constraint per producer (cross product), each
//!   piece checked empty.
//! * **`L102` reachability** — `{cond ∧ space ∧ requires}` integer-empty
//!   means the statement never executes: a warning.

use crate::polyhedral::guard::fm_feasible;
use crate::polyhedral::{AffineExpr, Constraint, ParamSpace};
use crate::pra::{
    CondConstraint, IndexMap, Lhs, Operand, Pra, Statement, TensorDim,
};

use super::{Finding, LintCode, LintOptions};

/// Cross products of producer-condition negations larger than this are
/// not expanded; the read is then *reported* as unproven (`L101` is a
/// deny lint — conservatism must point toward rejection, never toward
/// silently skipping a proof).
const MAX_COVERAGE_PIECES: usize = 4096;

/// Combined-space Fourier–Motzkin context: variables
/// `i0..i{n−1}, N0.., p0..` — the iteration vector ahead of the PRA's
/// own parameters, so statement conditions, access functions, and the
/// PRA's `requires` preconditions all embed as plain [`Constraint`]s
/// over one space.
pub(crate) struct FmCtx {
    nd: usize,
    total: usize,
    /// The combined space (for rendering constraints in messages).
    pub(crate) space: ParamSpace,
    /// Combined index of each loop bound `N_ℓ`.
    n_idx: Vec<usize>,
    /// The PRA's parameter preconditions, lifted into the combined
    /// space.
    requires: Vec<Constraint>,
}

impl FmCtx {
    pub(crate) fn new(pra: &Pra) -> Self {
        let nd = pra.ndims;
        let np = pra.space.len();
        let total = nd + np;
        let mut names: Vec<String> =
            (0..nd).map(|l| format!("i{l}")).collect();
        names.extend(pra.space.names().iter().cloned());
        let space = ParamSpace::new(names);
        let n_idx = (0..nd).map(|l| nd + pra.space.n_index(l)).collect();
        let requires = pra
            .requires
            .iter()
            .map(|c| {
                let mut coeffs = vec![0i64; total];
                coeffs[nd..].copy_from_slice(&c.0.coeffs);
                Constraint::ge0(AffineExpr { coeffs, konst: c.0.konst })
            })
            .collect();
        FmCtx { nd, total, space, n_idx, requires }
    }

    /// Parameter context: every loop bound at least `n_min`, plus the
    /// PRA's declared `requires` preconditions.
    pub(crate) fn context(&self, n_min: i64) -> Vec<Constraint> {
        let mut cs: Vec<Constraint> = self
            .n_idx
            .iter()
            .map(|&ni| {
                Constraint::ge0(AffineExpr::param_scaled(
                    self.total,
                    ni,
                    1,
                    -n_min,
                ))
            })
            .collect();
        cs.extend(self.requires.iter().cloned());
        cs
    }

    /// A statement condition `Σ a_ℓ·i_ℓ + konst(params) ≥ 0`, evaluated
    /// at the shifted point `i − shift`.
    pub(crate) fn cond(
        &self,
        c: &CondConstraint,
        shift: &[i64],
    ) -> Constraint {
        let mut coeffs = vec![0i64; self.total];
        coeffs[..self.nd].copy_from_slice(&c.a);
        coeffs[self.nd..].copy_from_slice(&c.konst.coeffs);
        let adj: i64 = c.a.iter().zip(shift).map(|(a, s)| a * s).sum();
        Constraint::ge0(AffineExpr { coeffs, konst: c.konst.konst - adj })
    }

    /// All of a statement's conditions at the point `i − shift`.
    pub(crate) fn conds(
        &self,
        s: &Statement,
        shift: &[i64],
    ) -> Vec<Constraint> {
        s.cond.iter().map(|c| self.cond(c, shift)).collect()
    }

    /// `i − shift` inside the rectangular iteration space:
    /// `0 ≤ i_ℓ − shift_ℓ ≤ N_ℓ − 1` for every dimension.
    pub(crate) fn in_space(&self, shift: &[i64]) -> Vec<Constraint> {
        let mut cs = Vec::with_capacity(2 * self.nd);
        for l in 0..self.nd {
            cs.push(Constraint::ge0(AffineExpr::param_scaled(
                self.total,
                l,
                1,
                -shift[l],
            )));
            let mut coeffs = vec![0i64; self.total];
            coeffs[l] = -1;
            coeffs[self.n_idx[l]] = 1;
            cs.push(Constraint::ge0(AffineExpr {
                coeffs,
                konst: shift[l] - 1,
            }));
        }
        cs
    }

    /// The `2n` half-spaces whose union is "`i − shift` outside the
    /// iteration space", each with a label for the finding message.
    pub(crate) fn out_of_space_pieces(
        &self,
        shift: &[i64],
    ) -> Vec<(String, Constraint)> {
        let mut out = Vec::with_capacity(2 * self.nd);
        for l in 0..self.nd {
            out.push((
                format!("below 0 in dimension {l}"),
                Constraint::ge0(AffineExpr::param_scaled(
                    self.total,
                    l,
                    -1,
                    shift[l] - 1,
                )),
            ));
            let mut coeffs = vec![0i64; self.total];
            coeffs[l] = 1;
            coeffs[self.n_idx[l]] = -1;
            out.push((
                format!("at or above N{l} in dimension {l}"),
                Constraint::ge0(AffineExpr { coeffs, konst: -shift[l] }),
            ));
        }
        out
    }

    /// One access-function row `Σ row_ℓ·i_ℓ + off` as a combined-space
    /// expression.
    pub(crate) fn access_expr(&self, row: &[i64], off: i64) -> AffineExpr {
        let mut coeffs = vec![0i64; self.total];
        coeffs[..self.nd].copy_from_slice(row);
        AffineExpr { coeffs, konst: off }
    }

    /// Declared extent of one tensor dimension.
    pub(crate) fn extent_expr(&self, dim: &TensorDim) -> AffineExpr {
        match dim {
            TensorDim::Param(i) => {
                AffineExpr::param(self.total, self.nd + i)
            }
            TensorDim::Fixed(v) => AffineExpr::constant(self.total, *v),
        }
    }

    /// Rational feasibility of the conjunction of all given constraint
    /// sets (`true` may still be integer-empty; `false` is a proof of
    /// emptiness).
    pub(crate) fn feasible(&self, sets: &[&[Constraint]]) -> bool {
        let refs: Vec<&Constraint> =
            sets.iter().flat_map(|s| s.iter()).collect();
        fm_feasible(&refs)
    }
}

pub(super) fn run(pra: &Pra, _opts: &LintOptions, out: &mut Vec<Finding>) {
    let ctx = FmCtx::new(pra);
    let base = ctx.context(1);
    let zero = vec![0i64; pra.ndims];
    let space_here = ctx.in_space(&zero);
    for s in &pra.statements {
        let conds = ctx.conds(s, &zero);
        bounds_safety(pra, &ctx, &base, &space_here, s, &conds, out);
        dependence_coverage(pra, &ctx, &base, &space_here, s, &conds, out);
        reachability(&ctx, &base, &space_here, s, &conds, out);
    }
}

/// `L100` for every tensor access of one statement.
fn bounds_safety(
    pra: &Pra,
    ctx: &FmCtx,
    base: &[Constraint],
    space_here: &[Constraint],
    s: &Statement,
    conds: &[Constraint],
    out: &mut Vec<Finding>,
) {
    let mut accesses: Vec<(&str, &IndexMap)> = s
        .args
        .iter()
        .filter_map(|a| match a {
            Operand::Tensor { name, map } => Some((name.as_str(), map)),
            Operand::Var { .. } => None,
        })
        .collect();
    if let Lhs::Tensor { name, map } = &s.lhs {
        accesses.push((name.as_str(), map));
    }
    for (tensor, map) in accesses {
        // Declared and rank-consistent: guaranteed by the structural
        // pass (L003/L005 block this pass otherwise).
        let decl = pra.tensor(tensor).expect("structural pass gated");
        for (r, (row, off)) in
            map.rows.iter().zip(&map.offset).enumerate()
        {
            let acc = ctx.access_expr(row, *off);
            let ext = ctx.extent_expr(&decl.shape[r]);
            let low = Constraint::ge0((-&acc).plus(-1));
            let high = Constraint::ge0(&acc - &ext);
            for (kind, viol) in
                [("below 0", low), ("at or above its extent", high)]
            {
                if ctx.feasible(&[
                    conds,
                    space_here,
                    base,
                    std::slice::from_ref(&viol),
                ]) {
                    out.push(Finding::new(
                        LintCode::L100,
                        Some(&s.name),
                        format!(
                            "access {tensor}[dim {r}] can index {kind} \
                             for admissible parameters (violation \
                             region {} is non-empty)",
                            viol.display(&ctx.space)
                        ),
                    ));
                }
            }
        }
    }
}

/// `L101` for every variable read of one statement.
fn dependence_coverage(
    pra: &Pra,
    ctx: &FmCtx,
    base: &[Constraint],
    space_here: &[Constraint],
    s: &Statement,
    conds: &[Constraint],
    out: &mut Vec<Finding>,
) {
    for arg in &s.args {
        let Operand::Var { name, dep } = arg else { continue };
        // 1) The read point i − d can leave the iteration space.
        let mut reported = false;
        for (label, piece) in ctx.out_of_space_pieces(dep) {
            if reported {
                break;
            }
            if ctx.feasible(&[
                conds,
                space_here,
                base,
                std::slice::from_ref(&piece),
            ]) {
                out.push(Finding::new(
                    LintCode::L101,
                    Some(&s.name),
                    format!(
                        "read {name}[i − {dep:?}] can land {label}, \
                         outside the iteration space"
                    ),
                ));
                reported = true;
            }
        }
        if reported {
            continue;
        }
        // 2) Inside the space, some producer of `name` must be active
        //    at i − d. Producers exist (L005 gates this pass), and an
        //    unconditioned producer covers everything.
        let producers: Vec<&Statement> = pra
            .statements
            .iter()
            .filter(|p| matches!(&p.lhs, Lhs::Var(v) if v == name))
            .collect();
        if producers.iter().any(|p| p.cond.is_empty()) {
            continue;
        }
        let pieces: usize = producers
            .iter()
            .map(|p| p.cond.len())
            .try_fold(1usize, |a, b| a.checked_mul(b))
            .unwrap_or(usize::MAX);
        if pieces > MAX_COVERAGE_PIECES {
            out.push(Finding::new(
                LintCode::L101,
                Some(&s.name),
                format!(
                    "coverage of read {name}[i − {dep:?}] needs {pieces} \
                     condition pieces (> {MAX_COVERAGE_PIECES}); \
                     refusing to assume it is covered"
                ),
            ));
            continue;
        }
        let space_there = ctx.in_space(dep);
        // Negated condition constraints per producer, at the read point.
        let negs: Vec<Vec<Constraint>> = producers
            .iter()
            .map(|p| {
                p.cond.iter().map(|c| ctx.cond(c, dep).negated()).collect()
            })
            .collect();
        // Cross product: one negated constraint per producer per piece.
        let mut sel = vec![0usize; negs.len()];
        'pieces: loop {
            let piece: Vec<Constraint> = sel
                .iter()
                .zip(&negs)
                .map(|(&k, n)| n[k].clone())
                .collect();
            if ctx.feasible(&[
                conds,
                space_here,
                &space_there,
                base,
                &piece,
            ]) {
                out.push(Finding::new(
                    LintCode::L101,
                    Some(&s.name),
                    format!(
                        "read {name}[i − {dep:?}] can land where no \
                         producer of {name} is active (uncovered piece: \
                         {})",
                        piece
                            .iter()
                            .map(|c| c.display(&ctx.space).to_string())
                            .collect::<Vec<_>>()
                            .join(" ∧ ")
                    ),
                ));
                break 'pieces;
            }
            // Odometer over the selections; done when it wraps.
            let mut j = 0;
            loop {
                if j == sel.len() {
                    break 'pieces;
                }
                sel[j] += 1;
                if sel[j] < negs[j].len() {
                    break;
                }
                sel[j] = 0;
                j += 1;
            }
        }
    }
}

/// `L102`: guard infeasible for every admissible parameter value.
fn reachability(
    ctx: &FmCtx,
    base: &[Constraint],
    space_here: &[Constraint],
    s: &Statement,
    conds: &[Constraint],
    out: &mut Vec<Finding>,
) {
    if !ctx.feasible(&[conds, space_here, base]) {
        out.push(Finding::new(
            LintCode::L102,
            Some(&s.name),
            "condition space is empty for every admissible parameter \
             value; the statement never executes"
                .into(),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::ParamSpace;
    use crate::pra::{Op, Statement, TensorDecl};

    fn lint(pra: &Pra) -> Vec<Finding> {
        let mut out = Vec::new();
        run(pra, &LintOptions::default(), &mut out);
        out
    }

    /// A 2-deep PRA reading `T[i1, i0]` (transposed) without declaring
    /// squareness: provably out of bounds at e.g. `N1 > N0` — but only
    /// symbolically, no concrete bounds ever exhibit it here.
    fn transposed(requires_square: bool) -> Pra {
        let nd = 2;
        let mut pra = Pra {
            name: "tr".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![Statement {
                name: "S1".into(),
                lhs: Lhs::Var("a".into()),
                op: Op::Copy,
                args: vec![Operand::tensor(
                    "T",
                    IndexMap::select(&[1, 0], nd),
                )],
                cond: vec![],
            }],
            tensors: vec![TensorDecl {
                name: "T".into(),
                shape: vec![TensorDim::Param(0), TensorDim::Param(1)],
            }],
            requires: vec![],
        };
        if requires_square {
            let np = pra.space.len();
            let n0 = AffineExpr::param(np, pra.space.n_index(0));
            let n1 = AffineExpr::param(np, pra.space.n_index(1));
            pra.requires.push(Constraint::ge(&n0, &n1));
            pra.requires.push(Constraint::le(&n0, &n1));
        }
        pra
    }

    #[test]
    fn transposed_access_oob_without_squareness() {
        let f = lint(&transposed(false));
        assert!(
            f.iter().any(|x| x.code == LintCode::L100),
            "transposed access must be L100 without N0 = N1: {f:?}"
        );
    }

    #[test]
    fn requires_precondition_discharges_the_proof() {
        let f = lint(&transposed(true));
        assert!(
            f.iter().all(|x| x.code != LintCode::L100),
            "N0 = N1 makes the transposed access safe: {f:?}"
        );
    }

    #[test]
    fn uncovered_read_is_l101() {
        // b reads a[i − (1,0)] everywhere, but a is only produced at
        // i0 = 0 — every read with i0 ≥ 2 lands where no producer ran.
        let nd = 2;
        let np = 2 * nd;
        let at0 = vec![
            CondConstraint::ge_const(0, 0, nd, np),
            CondConstraint::le_const(0, 0, nd, np),
        ];
        let pra = Pra {
            name: "unc".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![
                Statement {
                    name: "S1".into(),
                    lhs: Lhs::Var("a".into()),
                    op: Op::Copy,
                    args: vec![Operand::tensor(
                        "T",
                        IndexMap::select(&[1], nd),
                    )],
                    cond: at0,
                },
                Statement {
                    name: "S2".into(),
                    lhs: Lhs::Var("b".into()),
                    op: Op::Copy,
                    args: vec![Operand::var("a", vec![1, 0])],
                    cond: vec![CondConstraint::ge_const(0, 1, nd, np)],
                },
            ],
            tensors: vec![TensorDecl {
                name: "T".into(),
                shape: vec![TensorDim::Param(1)],
            }],
            requires: vec![],
        };
        let f = lint(&pra);
        assert!(
            f.iter()
                .any(|x| x.code == LintCode::L101
                    && x.statement.as_deref() == Some("S2")),
            "{f:?}"
        );
    }

    #[test]
    fn contradictory_guard_is_l102() {
        let nd = 1;
        let np = 2;
        let pra = Pra {
            name: "unr".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![Statement {
                name: "S1".into(),
                lhs: Lhs::Var("a".into()),
                op: Op::Copy,
                args: vec![Operand::tensor(
                    "T",
                    IndexMap::identity(1, nd),
                )],
                // i0 ≥ 2 ∧ i0 ≤ 1: empty for every N.
                cond: vec![
                    CondConstraint::ge_const(0, 2, nd, np),
                    CondConstraint::le_const(0, 1, nd, np),
                ],
            }],
            tensors: vec![TensorDecl {
                name: "T".into(),
                shape: vec![TensorDim::Param(0)],
            }],
            requires: vec![],
        };
        let f = lint(&pra);
        assert!(f.iter().any(|x| x.code == LintCode::L102), "{f:?}");
        // An empty statement's accesses are vacuously safe: no L100.
        assert!(f.iter().all(|x| x.code != LintCode::L100), "{f:?}");
    }
}
