//! Throughput of the schedule-vector enumerator and the structural
//! cheapness of the DSE schedule axis.
//!
//! Two measurements, appended to `BENCH_symbolic.json` (section
//! `schedule_enumeration`) for the CI perf trajectory:
//!
//! * **candidates/sec** — `schedule::enumerate_schedules` over every
//!   built-in workload phase on its canonical mapping: full symbolic
//!   `(permutation, λ^J, λ^K)` construction per causal permutation.
//! * **shared-analysis reuse ratio** — an all-schedules sweep
//!   (`DesignSpace::with_schedules(All)`) over shapes × bounds ×
//!   λ candidates, divided by the number of symbolic analyses it ran:
//!   how many evaluated design points each one-time analysis served.
//!   The λ expansion multiplies points, never analyses, so this must
//!   exceed the points-per-analysis ratio of the single-schedule sweep.
//!
//! ```bash
//! cargo bench --bench schedule_enumeration [-- --quick]
//! ```

use std::fmt::Write as _;

use tcpa_energy::bench_util::{
    bench, bench_symbolic_json_path, write_bench_section,
};
use tcpa_energy::dse::{
    explore_with_cache, AnalysisCache, DesignSpace, ExploreConfig,
    SchedulePolicy,
};
use tcpa_energy::schedule::enumerate_schedules;
use tcpa_energy::tiling::{pad_array, tile_pra, ArrayMapping};
use tcpa_energy::workloads;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 20 } else { 200 };

    // --- candidates/sec across every built-in workload phase ----------
    let wls = workloads::all();
    let tiled_phases: Vec<(String, tcpa_energy::tiling::TiledPra)> = wls
        .iter()
        .flat_map(|wl| {
            wl.phases.iter().map(|ph| {
                let t = pad_array(&[2, 2], ph.ndims);
                (ph.name.clone(), tile_pra(ph, &ArrayMapping::new(t)))
            })
        })
        .collect();
    let counts: Vec<usize> = tiled_phases
        .iter()
        .map(|(_, tiled)| enumerate_schedules(tiled, 1, None).len())
        .collect();
    let total_candidates: usize = counts.iter().sum();
    assert!(
        counts.iter().all(|&c| c >= 1),
        "every schedulable phase must enumerate at least one candidate"
    );
    let stats = bench(2, reps, || {
        tiled_phases
            .iter()
            .map(|(_, tiled)| enumerate_schedules(tiled, 1, None).len())
            .sum::<usize>()
    });
    let cand_per_sec =
        total_candidates as f64 / stats.median.as_secs_f64().max(1e-12);
    println!(
        "enumerate_schedules: {total_candidates} candidates over {} \
         phases, {} per pass — {cand_per_sec:.0} candidates/sec",
        tiled_phases.len(),
        stats.summary()
    );
    let mut per_phase_json = String::from("{");
    for (i, ((name, _), c)) in
        tiled_phases.iter().zip(&counts).enumerate()
    {
        let _ = write!(
            per_phase_json,
            "{}{name:?}: {c}",
            if i > 0 { ", " } else { "" }
        );
    }
    per_phase_json.push('}');

    // --- shared-analysis reuse across λ candidates at fixed shape -----
    let wl = workloads::by_name("gesummv").unwrap();
    let sizes: &[i64] = if quick { &[8, 16] } else { &[8, 16, 32, 64] };
    let space = |policy| {
        DesignSpace::new()
            .with_arrays_2d(8)
            .with_bounds_sweep(sizes, 2)
            .with_schedules(policy)
    };
    let run = |policy| {
        let cache = AnalysisCache::new();
        let res = explore_with_cache(
            &wl,
            &space(policy),
            &ExploreConfig::default(),
            &cache,
        );
        assert!(res.failures.is_empty(), "{:?}", res.failures);
        (res.points.len(), cache.stats().misses.max(1))
    };
    let (first_points, first_analyses) = run(SchedulePolicy::First);
    let (all_points, all_analyses) = run(SchedulePolicy::All);
    assert_eq!(
        first_analyses, all_analyses,
        "the λ axis must never add symbolic analyses"
    );
    let first_ratio = first_points as f64 / first_analyses as f64;
    let all_ratio = all_points as f64 / all_analyses as f64;
    assert!(
        all_ratio > first_ratio,
        "λ expansion must raise points-per-analysis: \
         {all_ratio:.1} vs {first_ratio:.1}"
    );
    println!(
        "reuse: {all_points} schedule-expanded points from \
         {all_analyses} analyses ({all_ratio:.1} evals/analysis; \
         single-schedule sweep: {first_ratio:.1})"
    );

    let body = format!(
        "{{\"total_candidates\": {total_candidates}, \
         \"candidates_per_sec\": {cand_per_sec:.1}, \
         \"per_phase_candidates\": {per_phase_json}, \
         \"sweep_points_all\": {all_points}, \
         \"sweep_points_first\": {first_points}, \
         \"analyses\": {all_analyses}, \
         \"reuse_ratio_all\": {all_ratio:.3}, \
         \"reuse_ratio_first\": {first_ratio:.3}, \
         \"quick\": {quick}}}"
    );
    let path = bench_symbolic_json_path();
    write_bench_section(&path, "schedule_enumeration", &body)
        .expect("writing BENCH_symbolic.json");
    println!("section schedule_enumeration → {}", path.display());
}
