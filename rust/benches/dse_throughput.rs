//! DSE throughput: the cache-backed parallel explorer vs the legacy
//! serial sweep, on the paper's running example (GESUMMV).
//!
//! The workload is a *bounds sweep* — the axis the paper says is O(1) per
//! query once the symbolic analysis exists. The legacy `dse_sweep` re-ran
//! the full tiling/scheduling/counting pass for every (shape, bounds)
//! pair; the explorer analyzes each shape once, then evaluates every
//! bounds point against the cached expressions. Expected: ≥ 10× on the
//! already-analyzed sweep (in practice far more, since evaluation is
//! microseconds against milliseconds of analysis).
//!
//! ```bash
//! cargo bench --bench dse_throughput [-- --quick]
//! ```

use std::time::Instant;

use tcpa_energy::analysis::WorkloadAnalysis;
use tcpa_energy::dse::{
    explore_with_cache, AnalysisCache, DesignSpace, ExploreConfig,
};
use tcpa_energy::workloads;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[i64] =
        if quick { &[16, 32, 64] } else { &[16, 32, 64, 128, 256] };
    let max_pes = 16i64;
    let wl = workloads::by_name("gesummv").unwrap();

    // --- Legacy baseline: serial, analysis re-run per (shape, bounds). ---
    // (Reproduces the old coordinator::dse_sweep inner loop verbatim so
    // the comparison stays honest as the shim evolves.)
    let t0 = Instant::now();
    let mut serial_points = 0usize;
    for &n in sizes {
        for t0v in 1..=max_pes {
            for t1v in 1..=max_pes {
                if t0v * t1v > max_pes || t0v > n || t1v > n {
                    continue;
                }
                let ana =
                    WorkloadAnalysis::analyze_uniform(&wl, &[t0v, t1v]);
                let params: Vec<Vec<i64>> = ana
                    .phases
                    .iter()
                    .map(|ph| ph.params_for(&[n, n]))
                    .collect();
                let e = ana.energy_at(&params);
                let l = ana.latency_at(&params);
                std::hint::black_box((e.total, l));
                serial_points += 1;
            }
        }
    }
    let serial = t0.elapsed();
    println!(
        "legacy serial sweep : {serial_points:4} points in {serial:?} \
         (analysis re-run per point)"
    );

    // --- Explorer: warm the cache once (one bounds), then sweep. ---
    let cache = AnalysisCache::new();
    let warm_space = DesignSpace::new()
        .with_arrays_2d(max_pes)
        .with_bounds(vec![sizes[0], sizes[0]]);
    let t1 = Instant::now();
    explore_with_cache(&wl, &warm_space, &ExploreConfig::default(), &cache);
    let warm = t1.elapsed();

    let sweep_space = DesignSpace::new()
        .with_arrays_2d(max_pes)
        .with_bounds_sweep(sizes, 2);
    let t2 = Instant::now();
    let res = explore_with_cache(
        &wl,
        &sweep_space,
        &ExploreConfig::default(),
        &cache,
    );
    let cached = t2.elapsed();
    println!(
        "one-time analysis   : {:4} shapes in {warm:?}",
        res.cache.entries
    );
    println!(
        "cached parallel sweep: {:4} points in {cached:?} \
         ({} on frontier, {:.0}% cache hits)",
        res.points.len(),
        res.frontier.len(),
        res.cache.hit_rate() * 100.0
    );

    let speedup = serial.as_secs_f64() / cached.as_secs_f64().max(1e-12);
    println!("\nspeedup (cached+parallel vs legacy serial): {speedup:.1}x");
    assert!(
        res.points.len() >= serial_points,
        "explorer must cover at least the legacy points \
         ({} vs {serial_points})",
        res.points.len()
    );
    // Timing-independent invariant (safe on noisy CI runners): the
    // already-analyzed sweep must not have re-run a single symbolic
    // pass — which is what makes the wall-clock speedup structural.
    assert!(
        res.points.iter().all(|p| p.cache_hit),
        "bounds sweep re-ran analyses: {:?}",
        res.cache
    );
    // The wall-clock acceptance bound is enforced only on full local
    // runs; `--quick` (the CI smoke) just reports it.
    if !quick {
        assert!(
            speedup >= 10.0,
            "acceptance: already-analyzed bounds sweep must be >= 10x \
             the serial re-analysis, got {speedup:.1}x"
        );
    }
}
