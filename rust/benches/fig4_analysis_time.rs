//! Fig. 4 of the paper: analysis time, symbolic vs simulation-based, for
//! GESUMMV on an 8×8 PE array across increasing matrix sizes.
//!
//! Expected shape (the paper's claim): the symbolic series stays nearly
//! constant (< 0.5 s) while the simulation series grows with the N²
//! iteration-space volume. Counts must agree exactly at every point.
//!
//! Emits `results/fig4_analysis_time.csv`, an ASCII rendering, and a
//! machine-readable section (`fig4_analysis_time`) of
//! `BENCH_symbolic.json` for cross-PR perf tracking.

use std::fmt::Write as _;

use tcpa_energy::bench_util::{bench_symbolic_json_path, write_bench_section};
use tcpa_energy::coordinator::fig4_rows;
use tcpa_energy::report::{ascii_chart, write_csv, CsvTable};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[i64] = if quick {
        &[16, 32, 64]
    } else {
        &[16, 32, 64, 128, 256, 512, 1024]
    };
    println!("Fig. 4 — GESUMMV on 8x8: analysis time vs matrix size\n");
    println!(
        "{:>6} {:>18} {:>18} {:>14} {:>7}",
        "N", "symbolic (1-time)", "symbolic eval", "simulation", "exact"
    );
    let rows = fig4_rows(sizes);
    let mut table = CsvTable::new(vec![
        "N",
        "symbolic_analysis_s",
        "symbolic_eval_s",
        "simulation_s",
        "exact",
    ]);
    for r in &rows {
        println!(
            "{:>6} {:>17.4}s {:>17.6}s {:>13.4}s {:>7}",
            r.n, r.symbolic_s, r.symbolic_eval_s, r.simulation_s, r.exact
        );
        table.push(vec![
            r.n.to_string(),
            format!("{:.6}", r.symbolic_s),
            format!("{:.9}", r.symbolic_eval_s),
            format!("{:.6}", r.simulation_s),
            r.exact.to_string(),
        ]);
    }
    write_csv(&table, std::path::Path::new("results"), "fig4_analysis_time")
        .expect("writing results/fig4_analysis_time.csv");
    let chart = ascii_chart(
        "analysis time [log s] vs N (GESUMMV, 8x8)",
        &[
            (
                "symbolic total",
                rows.iter()
                    .map(|r| (r.n as f64, r.symbolic_s + r.symbolic_eval_s))
                    .collect(),
            ),
            (
                "simulation",
                rows.iter().map(|r| (r.n as f64, r.simulation_s)).collect(),
            ),
        ],
        64,
        16,
        true,
    );
    println!("\n{chart}");

    // Shape assertions — fail loudly if the reproduction regresses.
    assert!(rows.iter().all(|r| r.exact), "counts must match exactly");
    let first = &rows[0];
    let last = rows.last().unwrap();
    assert!(
        last.simulation_s > first.simulation_s * 4.0,
        "simulation time must grow with N"
    );
    assert!(
        last.symbolic_s + last.symbolic_eval_s < 1.0,
        "symbolic analysis must stay below 1 s (paper: < 0.5 s)"
    );
    println!(
        "speedup at N={}: {:.0}x",
        last.n,
        last.simulation_s / (last.symbolic_eval_s.max(1e-9))
    );

    // Machine-readable record for the perf trajectory.
    let mut rows_json = String::from("[");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            rows_json,
            "{}{{\"n\": {}, \"symbolic_s\": {:.9}, \
             \"symbolic_eval_s\": {:.9}, \"simulation_s\": {:.9}}}",
            if i > 0 { ", " } else { "" },
            r.n,
            r.symbolic_s,
            r.symbolic_eval_s,
            r.simulation_s
        );
    }
    rows_json.push(']');
    let body = format!(
        "{{\"rows\": {rows_json}, \"sim_over_eval_speedup_at_max_n\": \
         {:.1}, \"quick\": {quick}}}",
        last.simulation_s / (last.symbolic_eval_s.max(1e-9))
    );
    let path = bench_symbolic_json_path();
    write_bench_section(&path, "fig4_analysis_time", &body)
        .expect("writing BENCH_symbolic.json");
    println!(
        "results recorded → {} (section fig4_analysis_time)",
        path.display()
    );
}
