//! Microbenchmark of the polyhedral substrate (the ISL/Barvinok
//! substitute): one-time symbolic counting cost and per-evaluation cost,
//! per array size — supporting the paper's footnote 1 ("analysis time
//! remains on the order of 1 minute even for 50×50 arrays"; our
//! implementation is far below that).

use tcpa_energy::bench_util::{bench, time_once};
use tcpa_energy::polyhedral::{count_concrete, count_symbolic, SymbolicOptions};
use tcpa_energy::tiling::{tile_pra, ArrayMapping};
use tcpa_energy::workloads::gesummv::gesummv;

fn main() {
    println!("symbolic volume computation cost vs array size (GESUMMV S7)\n");
    println!(
        "{:>7} {:>16} {:>14} {:>12} {:>8}",
        "array", "symbolic count", "eval/query", "concrete", "pieces"
    );
    for t in [2i64, 4, 8, 16, 32, 50] {
        let pra = gesummv();
        let mapping = ArrayMapping::new(vec![t, t]);
        let tiled = tile_pra(&pra, &mapping);
        let s7 = tiled
            .statements
            .iter()
            .find(|s| s.base_name == "S7" && !s.is_inter_tile())
            .unwrap();
        let opts = SymbolicOptions::default();
        let (analysis_t, gs) = time_once(|| {
            count_symbolic(&s7.space, &mapping.t, &tiled.context, &opts)
        });
        let n = 8 * t; // p = 8 per PE
        let params = mapping.params_for(&[n, n]);
        let eval = bench(3, 20, || gs.eval(&params));
        let conc = bench(3, 20, || {
            count_concrete(&s7.space, &mapping.t, &params)
        });
        println!(
            "{:>4}x{:<3} {:>15.3?} {:>14.3?} {:>12.3?} {:>8}",
            t,
            t,
            analysis_t,
            eval.median,
            conc.median,
            gs.pieces.len()
        );
        // sanity: symbolic == concrete
        assert_eq!(
            gs.eval(&params),
            count_concrete(&s7.space, &mapping.t, &params)
        );
        if t == 50 {
            assert!(
                analysis_t.as_secs_f64() < 60.0,
                "50x50 must stay within the paper's minute"
            );
        }
    }
}
