//! Microbenchmark of the polyhedral substrate (the ISL/Barvinok
//! substitute): one-time symbolic counting cost and per-evaluation cost,
//! per array size — supporting the paper's footnote 1 ("analysis time
//! remains on the order of 1 minute even for 50×50 arrays"; our
//! implementation is far below that).
//!
//! Also measures the two core PR-3 speedups directly:
//!
//! * packed `Poly` arithmetic vs a naive clone-heavy `BTreeMap` reference
//!   (the pre-packing representation), on a counter-shaped workload;
//! * symbolic counting with a shared [`SymbolicCtx`] feasibility cache vs
//!   per-call caches, across all statements of GESUMMV.
//!
//! Results are appended to `BENCH_symbolic.json` (section
//! `volume_counting`) so CI tracks the perf trajectory across PRs.
//! `--quick` limits the array sweep for CI smoke runs.

use std::fmt::Write as _;

use tcpa_energy::bench_util::{
    bench, bench_symbolic_json_path, time_once, write_bench_section,
};
use tcpa_energy::polyhedral::{
    count_concrete, count_symbolic, count_symbolic_in, AffineExpr, Poly,
    SymbolicCtx, SymbolicOptions,
};
use tcpa_energy::tiling::{tile_pra, ArrayMapping};
use tcpa_energy::workloads::gesummv::gesummv;

/// The pre-packing `Poly`: exponent `Vec<u32>` keys in a `BTreeMap`,
/// clone-then-mutate ops, per-pair exponent allocation in `mul` — kept
/// here as the measured baseline (the test-side twin lives in
/// `tests/packed_diff.rs`).
mod reference {
    use std::collections::BTreeMap;
    use tcpa_energy::polyhedral::AffineExpr;

    #[derive(Clone)]
    pub struct RefPoly {
        nparams: usize,
        terms: BTreeMap<Vec<u32>, i128>,
    }

    impl RefPoly {
        pub fn zero(nparams: usize) -> Self {
            RefPoly { nparams, terms: BTreeMap::new() }
        }

        pub fn constant(nparams: usize, c: i128) -> Self {
            let mut p = Self::zero(nparams);
            if c != 0 {
                p.terms.insert(vec![0; nparams], c);
            }
            p
        }

        pub fn from_affine(e: &AffineExpr) -> Self {
            let n = e.nparams();
            let mut p = Self::zero(n);
            if e.konst != 0 {
                p.terms.insert(vec![0; n], e.konst as i128);
            }
            for (i, &c) in e.coeffs.iter().enumerate() {
                if c != 0 {
                    let mut ex = vec![0; n];
                    ex[i] = 1;
                    p.terms.insert(ex, c as i128);
                }
            }
            p
        }

        fn add_term(&mut self, expo: Vec<u32>, coeff: i128) {
            if coeff == 0 {
                return;
            }
            let entry = self.terms.entry(expo.clone()).or_insert(0);
            *entry += coeff;
            if *entry == 0 {
                self.terms.remove(&expo);
            }
        }

        pub fn add(&self, rhs: &Self) -> Self {
            let mut out = self.clone();
            for (e, &c) in &rhs.terms {
                out.add_term(e.clone(), c);
            }
            out
        }

        pub fn mul(&self, rhs: &Self) -> Self {
            let mut out = Self::zero(self.nparams);
            for (ea, &ca) in &self.terms {
                for (eb, &cb) in &rhs.terms {
                    let expo: Vec<u32> =
                        ea.iter().zip(eb).map(|(a, b)| a + b).collect();
                    out.add_term(expo, ca * cb);
                }
            }
            out
        }

        pub fn eval(&self, params: &[i64]) -> i128 {
            let mut acc = 0i128;
            for (e, &c) in &self.terms {
                let mut t = c;
                for (i, &pow) in e.iter().enumerate() {
                    for _ in 0..pow {
                        t *= params[i] as i128;
                    }
                }
                acc += t;
            }
            acc
        }
    }
}

/// Counter-shaped polynomial workload: per "cell", a product of affine
/// interval lengths, squared (degree 8), accumulated over all cells —
/// exactly the op mix of the symbolic counter's hot loop (4 parameters).
fn cells() -> Vec<Vec<AffineExpr>> {
    (0..24i64)
        .map(|c| {
            vec![
                AffineExpr { coeffs: vec![1, 0, -c, 0], konst: c + 1 },
                AffineExpr { coeffs: vec![0, 1, 0, -1], konst: 2 * c + 1 },
                AffineExpr { coeffs: vec![1, 1, -1, 0], konst: 3 - c },
                AffineExpr { coeffs: vec![0, -1, 2, 1], konst: c },
            ]
        })
        .collect()
}

fn packed_workload(cells: &[Vec<AffineExpr>], params: &[i64]) -> i128 {
    let np = params.len();
    let mut acc = Poly::zero(np);
    for fs in cells {
        let mut prod = Poly::constant(np, 1);
        for f in fs {
            prod = prod.mul(&Poly::from_affine(f));
        }
        prod.mul_into(&prod.clone(), &mut acc); // acc += prod²
    }
    acc.eval(params)
}

fn reference_workload(cells: &[Vec<AffineExpr>], params: &[i64]) -> i128 {
    use reference::RefPoly;
    let np = params.len();
    let mut acc = RefPoly::zero(np);
    for fs in cells {
        let mut prod = RefPoly::constant(np, 1);
        for f in fs {
            prod = prod.mul(&RefPoly::from_affine(f));
        }
        acc = acc.add(&prod.mul(&prod));
    }
    acc.eval(params)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[i64] =
        if quick { &[2, 4, 8, 16] } else { &[2, 4, 8, 16, 32, 50] };

    println!("symbolic volume computation cost vs array size (GESUMMV S7)\n");
    println!(
        "{:>7} {:>16} {:>14} {:>12} {:>8}",
        "array", "symbolic count", "eval/query", "concrete", "pieces"
    );
    let mut rows_json = String::from("[");
    for (ri, &t) in sizes.iter().enumerate() {
        let pra = gesummv();
        let mapping = ArrayMapping::new(vec![t, t]);
        let tiled = tile_pra(&pra, &mapping);
        let s7 = tiled
            .statements
            .iter()
            .find(|s| s.base_name == "S7" && !s.is_inter_tile())
            .unwrap();
        let opts = SymbolicOptions::default();
        let (analysis_t, gs) = time_once(|| {
            count_symbolic(&s7.space, &mapping.t, &tiled.context, &opts)
        });
        let n = 8 * t; // p = 8 per PE
        let params = mapping.params_for(&[n, n]);
        let eval = bench(3, 20, || gs.eval(&params));
        let conc = bench(3, 20, || {
            count_concrete(&s7.space, &mapping.t, &params)
        });
        println!(
            "{:>4}x{:<3} {:>15.3?} {:>14.3?} {:>12.3?} {:>8}",
            t,
            t,
            analysis_t,
            eval.median,
            conc.median,
            gs.pieces.len()
        );
        let _ = write!(
            rows_json,
            "{}{{\"array\": {t}, \"symbolic_s\": {:.9}, \
             \"eval_s\": {:.9}, \"concrete_s\": {:.9}, \"pieces\": {}}}",
            if ri > 0 { ", " } else { "" },
            analysis_t.as_secs_f64(),
            eval.median.as_secs_f64(),
            conc.median.as_secs_f64(),
            gs.pieces.len()
        );
        // sanity: symbolic == concrete
        assert_eq!(
            gs.eval(&params),
            count_concrete(&s7.space, &mapping.t, &params)
        );
        if t == 50 {
            assert!(
                analysis_t.as_secs_f64() < 60.0,
                "50x50 must stay within the paper's minute"
            );
        }
    }
    rows_json.push(']');

    // Packed Poly vs the naive BTreeMap reference on the counter op mix.
    let cs = cells();
    let params = [23i64, 17, 3, 2];
    assert_eq!(
        packed_workload(&cs, &params),
        reference_workload(&cs, &params),
        "packed and reference polynomials must agree exactly"
    );
    let packed = bench(3, 30, || packed_workload(&cs, &params));
    let naive = bench(3, 30, || reference_workload(&cs, &params));
    let poly_speedup =
        naive.median.as_secs_f64() / packed.median.as_secs_f64().max(1e-12);
    println!(
        "\npacked Poly vs BTreeMap reference (counter op mix): \
         {:.3?} vs {:.3?} → {poly_speedup:.1}x",
        packed.median, naive.median
    );
    assert!(
        poly_speedup >= 1.5,
        "packed Poly must clearly beat the clone-heavy reference \
         (measured {poly_speedup:.2}x; typical is well above 3x)"
    );

    // Shared feasibility cache across all statements of one analysis vs
    // per-call caches.
    let pra = gesummv();
    let mapping = ArrayMapping::new(vec![4, 4]);
    let tiled = tile_pra(&pra, &mapping);
    let opts = SymbolicOptions::default();
    let fresh = bench(2, 8, || {
        tiled
            .statements
            .iter()
            .map(|s| {
                count_symbolic(&s.space, &mapping.t, &tiled.context, &opts)
                    .pieces
                    .len()
            })
            .sum::<usize>()
    });
    let shared = bench(2, 8, || {
        let ctx = SymbolicCtx::new(&tiled.context);
        tiled
            .statements
            .iter()
            .map(|s| {
                count_symbolic_in(&s.space, &mapping.t, &ctx, &opts)
                    .pieces
                    .len()
            })
            .sum::<usize>()
    });
    let ctx_speedup =
        fresh.median.as_secs_f64() / shared.median.as_secs_f64().max(1e-12);
    println!(
        "shared SymbolicCtx vs per-call caches (GESUMMV, 4x4): \
         {:.3?} vs {:.3?} → {ctx_speedup:.2}x",
        shared.median, fresh.median
    );

    let body = format!(
        "{{\"rows\": {rows_json}, \
         \"poly_mul_packed_s\": {:.9}, \"poly_mul_reference_s\": {:.9}, \
         \"poly_speedup\": {poly_speedup:.3}, \
         \"ctx_shared_s\": {:.9}, \"ctx_fresh_s\": {:.9}, \
         \"ctx_speedup\": {ctx_speedup:.3}, \"quick\": {quick}}}",
        packed.median.as_secs_f64(),
        naive.median.as_secs_f64(),
        shared.median.as_secs_f64(),
        fresh.median.as_secs_f64(),
    );
    let path = bench_symbolic_json_path();
    write_bench_section(&path, "volume_counting", &body)
        .expect("writing BENCH_symbolic.json");
    println!(
        "\nresults recorded → {} (section volume_counting)",
        path.display()
    );
}
