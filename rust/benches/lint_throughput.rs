//! Throughput of the lint front gate, and the property that makes it a
//! front gate at all: the symbolic proofs cost the same no matter how
//! large the loop bounds are.
//!
//! Two measurements, appended to `BENCH_symbolic.json` (section `lint`)
//! for the CI perf trajectory:
//!
//! * **phases/sec** — full three-pass lint (structural + Fourier–Motzkin
//!   polyhedral proofs + mapping hazards on a canonical array) over every
//!   built-in workload phase.
//! * **bounds-independence ratio** — the same lint with the admissible
//!   parameter region pinned to a 1× problem (`N_ℓ ≥ 2`) versus a 100×
//!   problem (`N_ℓ ≥ 200`) via `requires`. A sampling-based checker
//!   would slow down with the region; the FM emptiness proofs see the
//!   same constraint systems with different constants, so the ratio must
//!   stay near 1 (asserted ≤ 3× to absorb timer noise).
//!
//! ```bash
//! cargo bench --bench lint_throughput [-- --quick]
//! ```

use tcpa_energy::bench_util::{
    bench, bench_symbolic_json_path, write_bench_section,
};
use tcpa_energy::lint::{lint_workload, LintOptions};
use tcpa_energy::polyhedral::{AffineExpr, Constraint};
use tcpa_energy::pra::Workload;
use tcpa_energy::workloads;

/// Pin every loop bound to at least `n_min` via `requires` — same
/// constraint system shape at every scale, only the constants move.
fn with_min_bounds(wl: &Workload, n_min: i64) -> Workload {
    let mut wl = wl.clone();
    for phase in &mut wl.phases {
        let np = phase.space.len();
        for l in 0..phase.ndims {
            let idx = phase.space.n_index(l);
            phase
                .requires
                .push(Constraint::ge0(AffineExpr::param(np, idx).plus(-n_min)));
        }
    }
    wl
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 5 } else { 40 };

    let opts = LintOptions { array: Some(vec![2, 2]), ..Default::default() };
    let wls = workloads::all();
    let phases: usize = wls.iter().map(|w| w.phases.len()).sum();

    // Sanity outside the timed region: every builtin is deny-clean with
    // all three passes running, at both scales.
    for scale in [2i64, 200] {
        for wl in &wls {
            for rep in lint_workload(&with_min_bounds(wl, scale), &opts) {
                assert!(
                    !rep.has_deny(),
                    "scale {scale}, {}:\n{}",
                    rep.pra,
                    rep.render()
                );
            }
        }
    }

    let lint_all = |wls: &[Workload]| -> usize {
        wls.iter()
            .flat_map(|wl| lint_workload(wl, &opts))
            .map(|rep| rep.findings.len())
            .sum()
    };

    let stats = bench(2, reps, || lint_all(&wls));
    let per_sec = phases as f64 / stats.median.as_secs_f64().max(1e-12);
    println!(
        "lint: {phases} phases, three passes each, {} per sweep — \
         {per_sec:.0} phases/sec",
        stats.summary()
    );

    let small: Vec<Workload> =
        wls.iter().map(|w| with_min_bounds(w, 2)).collect();
    let large: Vec<Workload> =
        wls.iter().map(|w| with_min_bounds(w, 200)).collect();
    let t_small = bench(2, reps, || lint_all(&small));
    let t_large = bench(2, reps, || lint_all(&large));
    let ratio = t_large.median.as_secs_f64()
        / t_small.median.as_secs_f64().max(1e-12);
    println!(
        "bounds-independence: 1× {:?} vs 100× {:?} (ratio {ratio:.2})",
        t_small.median, t_large.median
    );
    assert!(
        ratio <= 3.0,
        "lint cost must not scale with loop bounds: 100×/1× ratio \
         {ratio:.2}"
    );

    let body = format!(
        "{{\"phases\": {phases}, \
         \"phases_per_sec\": {per_sec:.1}, \
         \"median_us\": {:.1}, \
         \"median_us_bounds_1x\": {:.1}, \
         \"median_us_bounds_100x\": {:.1}, \
         \"bounds_ratio\": {ratio:.3}, \
         \"quick\": {quick}}}",
        stats.median.as_secs_f64() * 1e6,
        t_small.median.as_secs_f64() * 1e6,
        t_large.median.as_secs_f64() * 1e6,
    );
    let path = bench_symbolic_json_path();
    write_bench_section(&path, "lint", &body)
        .expect("writing BENCH_symbolic.json");
    println!("section lint → {}", path.display());
}
