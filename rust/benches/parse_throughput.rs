//! Throughput of the textual workload frontend (`workloads::text`), and
//! the property that makes `--workload-file` safe to put in front of
//! every command: parsing + linting an untrusted file costs the same no
//! matter how large the declared problem is.
//!
//! Two measurements, appended to `BENCH_symbolic.json` (section
//! `frontend`) for the CI perf trajectory:
//!
//! * **files/sec** — lex + parse + lower + full lint over the whole
//!   `examples/workloads/` corpus (sources read once, outside the timed
//!   region).
//! * **bounds-independence ratio** — every builtin rendered to text with
//!   its admissible region pinned to a 1× problem (`N_ℓ ≥ 2`) versus a
//!   100× problem (`N_ℓ ≥ 200`) via `requires`, then parsed + linted.
//!   The text differs only in constants and the symbolic proofs see the
//!   same constraint systems, so the ratio must stay near 1 (asserted
//!   ≤ 3× to absorb timer noise).
//!
//! ```bash
//! cargo bench --bench parse_throughput [-- --quick]
//! ```

use tcpa_energy::bench_util::{
    bench, bench_symbolic_json_path, write_bench_section,
};
use tcpa_energy::lint::{lint_workload, LintOptions};
use tcpa_energy::polyhedral::{AffineExpr, Constraint};
use tcpa_energy::pra::Workload;
use tcpa_energy::workloads::{self, text};

/// Pin every loop bound to at least `n_min` via `requires` — the
/// rendered text keeps its shape at every scale, only constants move.
fn with_min_bounds(wl: &Workload, n_min: i64) -> Workload {
    let mut wl = wl.clone();
    for phase in &mut wl.phases {
        let np = phase.space.len();
        for l in 0..phase.ndims {
            let idx = phase.space.n_index(l);
            phase
                .requires
                .push(Constraint::ge0(AffineExpr::param(np, idx).plus(-n_min)));
        }
    }
    wl
}

/// Parse + lint one source; returns the finding count (kept live so the
/// work is not optimized away).
fn parse_and_lint(src: &str, opts: &LintOptions) -> usize {
    let wl = text::parse_workload(src).expect("corpus source must parse");
    lint_workload(&wl, opts).iter().map(|r| r.findings.len()).sum()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 5 } else { 40 };
    let opts = LintOptions::default();

    // The on-disk corpus, read once.
    let dir = format!(
        "{}/../examples/workloads",
        env!("CARGO_MANIFEST_DIR")
    );
    let mut corpus: Vec<String> = Vec::new();
    for entry in std::fs::read_dir(&dir).expect("examples/workloads") {
        let path = entry.expect("corpus entry").path();
        if path.extension().and_then(|e| e.to_str()) == Some("wl") {
            corpus.push(std::fs::read_to_string(&path).expect("corpus read"));
        }
    }
    assert!(corpus.len() >= 5, "corpus too small: {}", corpus.len());

    let files = corpus.len();
    let stats = bench(2, reps, || {
        corpus.iter().map(|s| parse_and_lint(s, &opts)).sum::<usize>()
    });
    let per_sec = files as f64 / stats.median.as_secs_f64().max(1e-12);
    println!(
        "frontend: {files} corpus files, parse+lint each, {} per sweep \
         — {per_sec:.0} files/sec",
        stats.summary()
    );

    // Bounds-independence: identical text shapes, constants 100× apart.
    let render_all = |n_min: i64| -> Vec<String> {
        workloads::all()
            .iter()
            .map(|w| text::render_workload(&with_min_bounds(w, n_min)))
            .collect()
    };
    let small = render_all(2);
    let large = render_all(200);
    let t_small = bench(2, reps, || {
        small.iter().map(|s| parse_and_lint(s, &opts)).sum::<usize>()
    });
    let t_large = bench(2, reps, || {
        large.iter().map(|s| parse_and_lint(s, &opts)).sum::<usize>()
    });
    let ratio = t_large.median.as_secs_f64()
        / t_small.median.as_secs_f64().max(1e-12);
    println!(
        "bounds-independence: 1× {:?} vs 100× {:?} (ratio {ratio:.2})",
        t_small.median, t_large.median
    );
    assert!(
        ratio <= 3.0,
        "parse+lint cost must not scale with loop bounds: 100×/1× \
         ratio {ratio:.2}"
    );

    let body = format!(
        "{{\"corpus_files\": {files}, \
         \"files_per_sec\": {per_sec:.1}, \
         \"median_us\": {:.1}, \
         \"median_us_bounds_1x\": {:.1}, \
         \"median_us_bounds_100x\": {:.1}, \
         \"bounds_ratio\": {ratio:.3}, \
         \"quick\": {quick}}}",
        stats.median.as_secs_f64() * 1e6,
        t_small.median.as_secs_f64() * 1e6,
        t_large.median.as_secs_f64() * 1e6,
    );
    let path = bench_symbolic_json_path();
    write_bench_section(&path, "frontend", &body)
        .expect("writing BENCH_symbolic.json");
    println!("section frontend → {}", path.display());
}
