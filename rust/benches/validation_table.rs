//! §V-A validation table: for all eight PolyBench benchmarks and several
//! (size, array) configurations, compare symbolic vs simulated access
//! counts and energies — the paper's "match exactly" table — and report
//! the per-configuration analysis/simulation times.
//!
//! Emits `results/validation_table.csv`.

use tcpa_energy::coordinator::validate_workload;
use tcpa_energy::report::{write_csv, CsvTable};
use tcpa_energy::workloads;

fn main() {
    let mut table = CsvTable::new(vec![
        "workload",
        "phase",
        "bounds",
        "array",
        "exact",
        "functional",
        "E_sym_pJ",
        "E_sim_pJ",
        "sym_eval_us",
        "sim_us",
    ]);
    let mut all_ok = true;
    println!(
        "{:<10} {:<9} {:<10} {:<8} {:>7} {:>11} {:>14} {:>11} {:>9}",
        "workload", "phase", "bounds", "array", "exact", "functional",
        "E_sym [pJ]", "eval [µs]", "sim [µs]"
    );
    for wl in workloads::all() {
        let size_sets: Vec<Vec<i64>> = match wl.name.as_str() {
            "jacobi1d" => vec![vec![4, 12], vec![6, 24]],
            "mvt" | "syrk" => vec![vec![8, 8], vec![16, 16]],
            _ => vec![vec![8, 8], vec![16, 12]],
        };
        for bounds in size_sets {
            for array in [vec![2, 2], vec![4, 4]] {
                for row in validate_workload(&wl, &bounds, &array) {
                    all_ok &= row.exact_match && row.functional_ok;
                    println!(
                        "{:<10} {:<9} {:<10} {:<8} {:>7} {:>11} {:>14.1} \
                         {:>11.0} {:>9.0}",
                        row.workload,
                        row.phase,
                        format!("{:?}", row.bounds),
                        format!("{:?}", row.array),
                        row.exact_match,
                        row.functional_ok,
                        row.energy_sym_pj,
                        row.sym_eval_us,
                        row.sim_us
                    );
                    table.push(vec![
                        row.workload.clone(),
                        row.phase.clone(),
                        format!("{:?}", row.bounds),
                        format!("{:?}", row.array),
                        row.exact_match.to_string(),
                        row.functional_ok.to_string(),
                        format!("{:.2}", row.energy_sym_pj),
                        format!("{:.2}", row.energy_sim_pj),
                        format!("{:.1}", row.sym_eval_us),
                        format!("{:.1}", row.sim_us),
                    ]);
                }
            }
        }
    }
    write_csv(&table, std::path::Path::new("results"), "validation_table")
        .expect("writing results/validation_table.csv");
    assert!(all_ok, "validation table contains mismatches");
    println!("\nall configurations: symbolic == simulated, exactly.");
}
