//! Ablation: tile-size sensitivity at fixed problem size.
//!
//! The symbolic formulas are parametric in `p` *independently* of `N`
//! (this is what distinguishes the paper from Timeloop-style analyses that
//! re-run per mapping): at fixed `N`, sweep tile sizes on a fixed 4×4
//! array and watch the FD↔ID traffic trade-off. Larger tiles keep more
//! dependencies PE-local (FD) and fewer crossing tiles (ID) — with energy
//! E(FD) = 0.35 > E(ID) = 0.24 per access but one IOb-free hop — while
//! DRAM traffic stays mapping-invariant. Also reports the Eq. 8 latency,
//! which penalizes undersized tiles that leave PEs idle.
//!
//! Emits `results/ablation_tile_size.csv`.

use tcpa_energy::analysis::SymbolicAnalysis;
use tcpa_energy::energy::MemoryClass;
use tcpa_energy::report::{write_csv, CsvTable};
use tcpa_energy::tiling::ArrayMapping;
use tcpa_energy::workloads;

fn main() {
    let wl = workloads::by_name("gesummv").unwrap();
    let phase = &wl.phases[0];
    let mapping = ArrayMapping::new(vec![4, 4]);
    // ONE symbolic analysis serves the whole sweep (p is a parameter!).
    let ana = SymbolicAnalysis::analyze(phase, &mapping);
    let n = 64i64;
    println!(
        "tile-size sweep: GESUMMV N={n}x{n} on a 4x4 array (one analysis)\n"
    );
    println!(
        "{:>7} {:>12} {:>12} {:>12} {:>12} {:>10} {:>12}",
        "p", "FD count", "ID count", "DR count", "E_tot [pJ]", "L [cyc]",
        "coverage"
    );
    let mut csv = CsvTable::new(vec![
        "p0", "p1", "fd", "id", "dram", "E_tot_pJ", "latency", "coverage",
    ]);
    let full_iters = (n * n) as i128;
    let mut results = Vec::new();
    for p in [4i64, 8, 16, 24, 32] {
        let params = vec![n, n, p, p];
        let c = ana.counts_at(&params);
        let e = ana.energy_at(&params);
        let l = ana.latency_at(&params);
        // Coverage: with t=4, p<16 leaves iterations unmapped; p=16 is the
        // exact cover; p>16 pads. The compute volume shows it directly.
        let s3 = ana
            .statements
            .iter()
            .find(|s| s.base_name == "S3")
            .unwrap()
            .volume
            .eval(&params);
        let coverage = s3 as f64 / full_iters as f64;
        println!(
            "{p:>4}x{p:<2} {:>12} {:>12} {:>12} {:>12.1} {:>10} {:>11.0}%",
            c.mem.get(&MemoryClass::Fd).copied().unwrap_or(0),
            c.mem.get(&MemoryClass::Id).copied().unwrap_or(0),
            c.mem.get(&MemoryClass::Dram).copied().unwrap_or(0),
            e.total,
            l,
            coverage * 100.0
        );
        csv.push(vec![
            p.to_string(),
            p.to_string(),
            c.mem.get(&MemoryClass::Fd).copied().unwrap_or(0).to_string(),
            c.mem.get(&MemoryClass::Id).copied().unwrap_or(0).to_string(),
            c.mem.get(&MemoryClass::Dram).copied().unwrap_or(0).to_string(),
            format!("{:.1}", e.total),
            l.to_string(),
            format!("{coverage:.3}"),
        ]);
        results.push((p, c, coverage));
    }
    write_csv(&csv, std::path::Path::new("results"), "ablation_tile_size")
        .expect("writing results/ablation_tile_size.csv");

    // Shape assertions at the exact cover (p = 16 = N/t):
    let exact = results.iter().find(|(p, _, _)| *p == 16).unwrap();
    assert!((exact.2 - 1.0).abs() < 1e-9, "p=N/t must cover exactly");
    // Growing p within full coverage shifts ID → FD traffic.
    let p16 = &results.iter().find(|(p, _, _)| *p == 16).unwrap().1;
    let p32 = &results.iter().find(|(p, _, _)| *p == 32).unwrap().1;
    let fd = |c: &tcpa_energy::analysis::CountsBreakdown| {
        c.mem.get(&MemoryClass::Fd).copied().unwrap_or(0)
    };
    let id = |c: &tcpa_energy::analysis::CountsBreakdown| {
        c.mem.get(&MemoryClass::Id).copied().unwrap_or(0)
    };
    assert!(fd(p32) >= fd(p16), "bigger tiles keep more deps local");
    assert!(id(p32) <= id(p16), "bigger tiles cross fewer boundaries");
    println!("\ntile-size trade-off confirmed: FD grows, ID shrinks with p.");
}
