//! Fig. 5 of the paper: total energy `E_tot` and latency `L` vs matrix
//! size for GEMM on an 8×8 PE grid, with the per-access-location energy
//! breakdown.
//!
//! Expected shape: both grow ~N³; DRAM dominates at small N, while the
//! on-chip share (FD/RD registers + compute) grows with N as tiles grow
//! (tile size p = N/8 ⇒ more intra-tile reuse per DRAM element).
//!
//! Emits `results/fig5_energy_scaling.csv` and ASCII charts.

use tcpa_energy::coordinator::fig5_rows;
use tcpa_energy::report::{ascii_chart, write_csv, CsvTable};

fn main() {
    let sizes: &[i64] = &[16, 32, 64, 128, 256, 512, 1024];
    println!("Fig. 5 — GEMM on 8x8: energy + latency vs matrix size\n");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "N", "total pJ", "DRAM", "IOb", "FD", "RD", "compute", "L cycles"
    );
    let rows = fig5_rows(sizes);
    let mut table = CsvTable::new(vec![
        "N", "total_pj", "DR_pj", "IOb_pj", "FD_pj", "RD_pj", "ID_pj",
        "OD_pj", "compute_pj", "latency_cycles",
    ]);
    for r in &rows {
        println!(
            "{:>6} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e} \
             {:>12.3e} {:>10}",
            r.n, r.total_pj, r.dram_pj, r.iob_pj, r.fd_pj, r.rd_pj,
            r.compute_pj, r.latency_cycles
        );
        table.push(vec![
            r.n.to_string(),
            format!("{:.1}", r.total_pj),
            format!("{:.1}", r.dram_pj),
            format!("{:.1}", r.iob_pj),
            format!("{:.1}", r.fd_pj),
            format!("{:.1}", r.rd_pj),
            format!("{:.1}", r.id_pj),
            format!("{:.1}", r.od_pj),
            format!("{:.1}", r.compute_pj),
            r.latency_cycles.to_string(),
        ]);
    }
    write_csv(&table, std::path::Path::new("results"), "fig5_energy_scaling")
        .expect("writing results/fig5_energy_scaling.csv");
    println!(
        "\n{}",
        ascii_chart(
            "GEMM energy breakdown [log pJ] vs N (8x8 grid)",
            &[
                ("total", rows.iter().map(|r| (r.n as f64, r.total_pj)).collect()),
                ("DRAM", rows.iter().map(|r| (r.n as f64, r.dram_pj)).collect()),
                (
                    "FD+RD",
                    rows.iter()
                        .map(|r| (r.n as f64, r.fd_pj + r.rd_pj))
                        .collect()
                ),
                (
                    "compute",
                    rows.iter().map(|r| (r.n as f64, r.compute_pj)).collect()
                ),
            ],
            64,
            18,
            true,
        )
    );
    println!(
        "{}",
        ascii_chart(
            "GEMM latency [log cycles] vs N (8x8 grid)",
            &[(
                "latency",
                rows.iter()
                    .map(|r| (r.n as f64, r.latency_cycles as f64))
                    .collect()
            )],
            64,
            12,
            true,
        )
    );

    // Shape assertions (the paper's qualitative findings).
    let dram_share =
        |r: &tcpa_energy::coordinator::Fig5Row| r.dram_pj / r.total_pj;
    let onchip_share = |r: &tcpa_energy::coordinator::Fig5Row| {
        (r.fd_pj + r.rd_pj + r.compute_pj) / r.total_pj
    };
    let first = &rows[0];
    let last = rows.last().unwrap();
    assert!(
        dram_share(first) > dram_share(last),
        "DRAM share must shrink with N: {:.3} vs {:.3}",
        dram_share(first),
        dram_share(last)
    );
    assert!(
        onchip_share(last) > onchip_share(first),
        "on-chip share must grow with N"
    );
    assert!(
        last.total_pj > first.total_pj && last.latency_cycles > first.latency_cycles,
        "energy and latency grow with problem size"
    );
    println!(
        "DRAM share: {:.1}% at N={} → {:.1}% at N={} (on-chip+compute: \
         {:.1}% → {:.1}%)",
        100.0 * dram_share(first),
        first.n,
        100.0 * dram_share(last),
        last.n,
        100.0 * onchip_share(first),
        100.0 * onchip_share(last),
    );
}
