//! Beam search vs the exhaustive oracle just under the refusal cap.
//!
//! `dse --strategy beam` exists for exactly one regime: per-phase shape
//! spaces too big to enumerate comfortably but too interesting to
//! refuse. This bench pits the default-budget beam against the
//! exhaustive sweep on the largest gemver per-phase space *under* the
//! CLI's 20 000-point cap (27 shapes ^ 3 phases = 19 683 combinations;
//! `--quick` shrinks to 8 ^ 3 = 512 for the CI smoke), recording
//! points evaluated, wall clock, and the beam's knee-energy regret in
//! a `strategy` section of `BENCH_symbolic.json`.
//!
//! Acceptance (full runs only; `--quick` just reports): the beam
//! evaluates strictly fewer points than the oracle and its knee stays
//! within 5% energy of the oracle's knee.
//!
//! ```bash
//! cargo bench --bench strategy_search [-- --quick]
//! ```

use tcpa_energy::bench_util::{
    bench_symbolic_json_path, time_once, write_bench_section,
};
use tcpa_energy::dse::{
    explore_with_cache, AnalysisCache, DesignSpace, ExploreConfig,
    ExploreResult, PhasePolicy, Strategy, DEFAULT_BEAM_WIDTH,
};
use tcpa_energy::workloads;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // 27 shapes ^ 3 phases = 19 683 — the largest per-phase gemver
    // space under the CLI's 20 000-point exhaustive cap.
    let max_pes = if quick { 4 } else { 10 };

    let wl = workloads::by_name("gemver").unwrap();
    let space = DesignSpace::new()
        .with_arrays_2d(max_pes)
        .with_bounds(vec![32, 32])
        .with_phase_shapes(PhasePolicy::PerPhase);
    let cfg = ExploreConfig::default();

    // One shared cache: the per-(phase, shape) analyses are paid once,
    // so both strategies race on search + evaluation, not on symbolic
    // analysis.
    let cache = AnalysisCache::new();
    let (wall_ex, oracle) = time_once(|| {
        explore_with_cache(&wl, &space, &cfg, &cache)
    });
    let beam_space =
        space.clone().with_strategy(Strategy::beam(DEFAULT_BEAM_WIDTH));
    let (wall_beam, beam) = time_once(|| {
        explore_with_cache(&wl, &beam_space, &cfg, &cache)
    });

    let knee_e = |r: &ExploreResult| {
        r.knee.map(|i| r.points[i].energy_pj).unwrap_or(f64::NAN)
    };
    let regret = knee_e(&beam) / knee_e(&oracle);
    let min_e = |r: &ExploreResult| {
        r.points
            .iter()
            .map(|p| p.energy_pj)
            .fold(f64::INFINITY, f64::min)
    };

    println!(
        "exhaustive: {:5} points in {wall_ex:?} (knee {:.1} pJ)",
        oracle.points.len(),
        knee_e(&oracle)
    );
    println!(
        "beam      : {:5} points in {wall_beam:?} (knee {:.1} pJ, \
         regret {:.4})",
        beam.points.len(),
        knee_e(&beam),
        regret
    );
    println!(
        "energy minimum: beam {:.1} pJ vs exhaustive {:.1} pJ",
        min_e(&beam),
        min_e(&oracle)
    );
    if !quick {
        assert!(
            beam.points.len() < oracle.points.len(),
            "acceptance: the beam must evaluate strictly fewer points \
             ({} vs {})",
            beam.points.len(),
            oracle.points.len()
        );
        assert!(
            regret <= 1.05,
            "acceptance: beam knee regret must stay within 5%, got \
             {regret:.4}"
        );
    }

    let body = format!(
        "{{\"workload\": \"gemver\", \"max_pes\": {max_pes}, \
         \"points_exhaustive\": {}, \"points_beam\": {}, \
         \"wall_ms_exhaustive\": {:.1}, \"wall_ms_beam\": {:.1}, \
         \"knee_regret\": {regret:.4}, \"quick\": {quick}}}",
        oracle.points.len(),
        beam.points.len(),
        wall_ex.as_secs_f64() * 1e3,
        wall_beam.as_secs_f64() * 1e3,
    );
    let path = bench_symbolic_json_path();
    write_bench_section(&path, "strategy", &body)
        .expect("writing BENCH_symbolic.json");
    println!("section strategy → {}", path.display());
}
