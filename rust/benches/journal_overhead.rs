//! Overhead of checkpoint journalling on an already-analyzed sweep.
//!
//! The checkpoint journal (`dse --checkpoint`) is only free if nobody
//! notices it: the records are written batched through tmp+rename off
//! the hot path, so a cached sweep — the worst case, where per-point
//! work is microseconds of expression evaluation rather than
//! milliseconds of symbolic analysis — must cost nearly the same with
//! and without the journal. This bench times the same cached sweep
//! plain vs journalled and appends a `journal` section to
//! `BENCH_symbolic.json` for the CI perf trajectory.
//!
//! Acceptance (full runs only; `--quick` is the CI smoke and just
//! reports): journalling adds ≤ 5% to the cached sweep's median.
//!
//! ```bash
//! cargo bench --bench journal_overhead [-- --quick]
//! ```

use tcpa_energy::bench_util::{
    bench, bench_symbolic_json_path, write_bench_section,
};
use tcpa_energy::dse::{
    explore_controlled, AnalysisCache, DesignSpace, ExploreConfig,
    ExploreControl,
};
use tcpa_energy::workloads;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 5 } else { 30 };

    let wl = workloads::by_name("gesummv").unwrap();
    let sizes: &[i64] = &[16, 32, 64, 128];
    let space = DesignSpace::new()
        .with_arrays_2d(16)
        .with_bounds_sweep(sizes, 2);
    let cfg = ExploreConfig::default();
    let cache = AnalysisCache::new();

    // Warm the cache outside the timed region: afterwards every point
    // is a pure evaluation, the regime where journal I/O could matter.
    let warm = explore_controlled(
        &wl,
        &space,
        &cfg,
        &cache,
        &ExploreControl::default(),
    )
    .unwrap();
    let n = warm.points.len();

    let plain = bench(2, reps, || {
        let res = explore_controlled(
            &wl,
            &space,
            &cfg,
            &cache,
            &ExploreControl::default(),
        )
        .unwrap();
        assert!(res.points.iter().all(|p| p.cache_hit));
        res.points.len()
    });

    let journal = std::env::temp_dir().join(format!(
        "tcpa-journal-overhead-{}.journal",
        std::process::id()
    ));
    let ctl = ExploreControl {
        checkpoint: Some(journal.clone()),
        ..Default::default()
    };
    let journalled = bench(2, reps, || {
        let res =
            explore_controlled(&wl, &space, &cfg, &cache, &ctl).unwrap();
        assert!(res.points.iter().all(|p| p.cache_hit));
        res.points.len()
    });
    assert!(journal.exists(), "the sweep must have written its journal");
    let journal_bytes =
        std::fs::metadata(&journal).map_or(0, |m| m.len());
    let _ = std::fs::remove_file(&journal);

    let ratio = journalled.median.as_secs_f64()
        / plain.median.as_secs_f64().max(1e-12);
    println!(
        "cached sweep, plain     : {n:4} points, {}",
        plain.summary()
    );
    println!(
        "cached sweep, journalled: {n:4} points, {} \
         ({journal_bytes} journal bytes)",
        journalled.summary()
    );
    println!("journalling overhead: {:.2}% ", (ratio - 1.0) * 100.0);
    if !quick {
        assert!(
            ratio <= 1.05,
            "acceptance: checkpointing must add <= 5% to a cached \
             sweep, got {:.2}%",
            (ratio - 1.0) * 100.0
        );
    }

    let body = format!(
        "{{\"points\": {n}, \
         \"median_us_plain\": {:.1}, \
         \"median_us_journalled\": {:.1}, \
         \"journal_bytes\": {journal_bytes}, \
         \"overhead_ratio\": {ratio:.4}, \
         \"quick\": {quick}}}",
        plain.median.as_secs_f64() * 1e6,
        journalled.median.as_secs_f64() * 1e6,
    );
    let path = bench_symbolic_json_path();
    write_bench_section(&path, "journal", &body)
        .expect("writing BENCH_symbolic.json");
    println!("section journal → {}", path.display());
}
