//! Ablation: register-hierarchy policies (§VI's "comparisons with other
//! loop nest accelerator architectures") and technology scaling.
//!
//! Reuses the *same* one-time symbolic volumes across all policies and
//! energy tables — demonstrating why symbolic analysis makes architecture
//! comparison cheap. Expected shape: removing feedback registers inflates
//! the energy of reuse-heavy kernels (GEMM: every `a`/`b` propagation and
//! the reduction chain spills to the I/O buffers); DRAM-bound kernels are
//! less sensitive. At a projected 7 nm node the DRAM share grows further
//! (on-chip energy scales faster than the DRAM interface).
//!
//! Emits `results/ablation_policies.csv`.

use tcpa_energy::analysis::SymbolicAnalysis;
use tcpa_energy::energy::{Backend, EnergyTable, Policy};
use tcpa_energy::report::{write_csv, CsvTable};
use tcpa_energy::tiling::ArrayMapping;
use tcpa_energy::workloads;

fn main() {
    let table45 = EnergyTable::table1_45nm();
    let table7 = table45.scaled(0.3, 0.12); // coarse 7 nm projection
    let mut csv = CsvTable::new(vec![
        "workload", "N", "policy", "node", "E_tot_pJ", "vs_tcpa45",
    ]);
    println!(
        "{:<10} {:>6} {:<9} {:>6} {:>16} {:>10}",
        "workload", "N", "policy", "node", "E_tot [pJ]", "vs tcpa"
    );
    for name in ["gesummv", "gemm", "bicg", "jacobi1d"] {
        let wl = workloads::by_name(name).unwrap();
        let phase = &wl.phases[0];
        let mut t = vec![8, 8];
        while t.len() < phase.ndims {
            t.push(1);
        }
        t.truncate(phase.ndims);
        let mapping = ArrayMapping::new(t);
        // One analysis ...
        let ana = SymbolicAnalysis::analyze(phase, &mapping);
        let n: i64 = if name == "jacobi1d" { 64 } else { 256 };
        let mut bounds = vec![n; phase.ndims];
        if name == "jacobi1d" {
            bounds[0] = 16; // sweeps
        }
        let params = ana.params_for(&bounds);
        // ... many architectures: the legacy policies as Backend
        // descriptors, retabled per technology node.
        let base = ana
            .energy_at_backend(&params, &Policy::Tcpa.backend(&table45))
            .total;
        for (node, table) in [("45nm", &table45), ("7nm", &table7)] {
            for policy in Policy::ALL {
                let e = ana
                    .energy_at_backend(&params, &policy.backend(table))
                    .total;
                println!(
                    "{name:<10} {n:>6} {:<9} {node:>6} {e:>16.3e} {:>9.2}x",
                    policy.label(),
                    e / base
                );
                csv.push(vec![
                    name.to_string(),
                    n.to_string(),
                    policy.label().to_string(),
                    node.to_string(),
                    format!("{e:.1}"),
                    format!("{:.3}", e / base),
                ]);
            }
        }
        // Shape assertions — including the cross-architecture builtins
        // (tcpa ≤ systolic ≤ cgra ≤ gpu-sm, pointwise per access).
        let priced: Vec<f64> = [
            Backend::tcpa(),
            Backend::systolic(),
            Backend::cgra(),
            Backend::gpu_sm(),
        ]
        .iter()
        .map(|b| ana.energy_at_backend(&params, b).total)
        .collect();
        assert!(
            priced.windows(2).all(|w| w[0] <= w[1]),
            "{name}: builtin backend chain out of order: {priced:?}"
        );
        let tcpa = ana
            .energy_at_backend(&params, &Policy::Tcpa.backend(&table45))
            .total;
        let nofd = ana
            .energy_at_backend(&params, &Policy::NoFeedback.backend(&table45))
            .total;
        let noreuse = ana
            .energy_at_backend(
                &params,
                &Policy::NoLocalReuse.backend(&table45),
            )
            .total;
        assert!(nofd >= tcpa, "{name}: removing FD can't save energy");
        assert!(noreuse >= nofd, "{name}: removing all reuse is worse still");
    }
    write_csv(&csv, std::path::Path::new("results"), "ablation_policies")
        .expect("writing results/ablation_policies.csv");
    println!("\nablation complete; policies ordered tcpa <= no-fd <= no-reuse.");
}
