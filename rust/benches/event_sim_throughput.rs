//! Tick engine vs discrete-event engine throughput across problem sizes.
//!
//! The tick engine materializes and sorts the full iteration space —
//! Θ(I log I) scheduling work and Θ(I) memory for I iterations. The
//! event engine replaces that with a time-ordered queue holding at most
//! one pending fire per PE, so its **per-iteration cost is
//! bounds-independent**: O(#statements + log #PEs), no global sort, no
//! event materialization. This bench measures both engines on growing
//! GESUMMV grids and records the trajectory in `BENCH_sim.json`
//! (section `event_sim_throughput`):
//!
//! * iterations/sec for each engine at every size,
//! * the event engine's ns/iteration — which must stay flat as the
//!   grid grows 256× (asserted at ≤ 2× drift between the smallest and
//!   largest size in full runs; `--quick`, the CI smoke, just reports).
//!
//! ```bash
//! cargo bench --bench event_sim_throughput [-- --quick]
//! ```

use std::fmt::Write as _;

use tcpa_energy::bench_util::{
    bench_sim_json_path, time_once, write_bench_section,
};
use tcpa_energy::schedule::find_schedule;
use tcpa_energy::sim::{simulate_event, simulate_tick, ArchConfig};
use tcpa_energy::tiling::tile_pra;
use tcpa_energy::workloads::{self, workload_inputs};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[i64] =
        if quick { &[64, 128, 256] } else { &[64, 128, 256, 512, 1024] };

    let wl = workloads::by_name("gesummv").unwrap();
    let phase = &wl.phases[0];
    let mut arch = ArchConfig::with_array(vec![8, 8]);
    arch.regs.fd = 1 << 20;
    let tiled = tile_pra(phase, &arch.mapping);
    let schedule = find_schedule(&tiled, arch.pi).unwrap();

    println!("tick vs event engine (GESUMMV, 8x8 array)\n");
    println!(
        "{:>6} {:>10} {:>12} {:>12} {:>14} {:>14}",
        "N", "iters", "tick", "event", "event it/s", "event ns/it"
    );
    let mut rows = String::from("[");
    let mut event_ns: Vec<f64> = Vec::new();
    for (i, &n) in sizes.iter().enumerate() {
        let params = arch.mapping.params_for(&[n, n]);
        let env = workload_inputs(&wl, &[params.clone()]);
        let (t_tick, tick) =
            time_once(|| simulate_tick(phase, &arch, &schedule, &params, &env));
        let (t_event, event) = time_once(|| {
            simulate_event(phase, &arch, &schedule, &params, &env)
        });
        // Throughput numbers for diverging engines would be garbage.
        assert_eq!(event.cycles, tick.cycles, "engine divergence at N={n}");
        assert_eq!(event.counters, tick.counters, "counters at N={n}");
        let iters: i64 = event.stats.pe.iter().map(|p| p.iterations).sum();
        assert_eq!(iters, n * n);
        let ns_per_iter =
            t_event.as_secs_f64() * 1e9 / iters as f64;
        event_ns.push(ns_per_iter);
        println!(
            "{:>6} {:>10} {:>12.3?} {:>12.3?} {:>14.3e} {:>14.1}",
            n,
            iters,
            t_tick,
            t_event,
            iters as f64 / t_event.as_secs_f64().max(1e-12),
            ns_per_iter
        );
        let _ = write!(
            rows,
            "{}{{\"n\": {n}, \"iters\": {iters}, \
             \"tick_s\": {:.6}, \"event_s\": {:.6}, \
             \"tick_iters_per_sec\": {:.1}, \
             \"event_iters_per_sec\": {:.1}, \
             \"event_ns_per_iter\": {ns_per_iter:.2}}}",
            if i > 0 { ", " } else { "" },
            t_tick.as_secs_f64(),
            t_event.as_secs_f64(),
            iters as f64 / t_tick.as_secs_f64().max(1e-12),
            iters as f64 / t_event.as_secs_f64().max(1e-12),
        );
    }
    rows.push(']');

    // Bounds-independence: the event engine's per-iteration cost must
    // not grow with the grid. Full runs enforce it; `--quick` (the CI
    // smoke, noisy shared runners) just reports the ratio.
    let first = event_ns.first().copied().unwrap();
    let last = event_ns.last().copied().unwrap();
    let drift = last / first.max(1e-12);
    println!(
        "\nevent ns/iter: {first:.1} @ N={} → {last:.1} @ N={} \
         ({drift:.2}x)",
        sizes[0],
        sizes[sizes.len() - 1]
    );
    if !quick {
        assert!(
            drift <= 2.0,
            "event per-iteration cost grew {drift:.2}x from N={} to \
             N={} — not bounds-independent",
            sizes[0],
            sizes[sizes.len() - 1]
        );
    }

    let body = format!(
        "{{\"workload\": \"gesummv\", \"array\": \"8x8\", \
         \"rows\": {rows}, \"event_ns_per_iter_drift\": {drift:.3}, \
         \"quick\": {quick}}}"
    );
    let path = bench_sim_json_path();
    write_bench_section(&path, "event_sim_throughput", &body)
        .expect("writing BENCH_sim.json");
    println!("section event_sim_throughput → {}", path.display());
}
