//! Simulator throughput benchmark: statement-executions per second of the
//! cycle-accurate engine across problem sizes (the denominator of the
//! Fig. 4 comparison, and the §Perf optimization target for L3).

use tcpa_energy::bench_util::time_once;
use tcpa_energy::schedule::find_schedule;
use tcpa_energy::sim::{simulate, ArchConfig};
use tcpa_energy::tiling::{tile_pra, ArrayMapping};
use tcpa_energy::workloads::{self, workload_inputs};

fn main() {
    let wl = workloads::by_name("gesummv").unwrap();
    let phase = &wl.phases[0];
    let mapping = ArrayMapping::new(vec![8, 8]);
    let tiled = tile_pra(phase, &mapping);
    let schedule = find_schedule(&tiled, 1).unwrap();
    println!("simulator throughput (GESUMMV, 8x8 array)\n");
    println!(
        "{:>6} {:>14} {:>12} {:>16}",
        "N", "stmt execs", "wall", "execs/s"
    );
    for n in [64i64, 128, 256, 512] {
        let params = mapping.params_for(&[n, n]);
        let env = workload_inputs(&wl, &[params.clone()]);
        let mut arch = ArchConfig::with_array(vec![8, 8]);
        arch.regs.fd = 1 << 20;
        let (t, res) =
            time_once(|| simulate(phase, &arch, &schedule, &params, &env));
        let execs = res.counters.executions;
        println!(
            "{:>6} {:>14} {:>12.3?} {:>16.3e}",
            n,
            execs,
            t,
            execs as f64 / t.as_secs_f64()
        );
    }
}
