//! Simulator throughput benchmark: statement-executions per second of the
//! cycle-accurate engine across problem sizes (the denominator of the
//! Fig. 4 comparison, and the §Perf optimization target for L3).
//!
//! Results land in `BENCH_sim.json` (section `simulator_throughput`),
//! alongside the tick-vs-event comparison of `event_sim_throughput`.
//!
//! ```bash
//! cargo bench --bench simulator_throughput [-- --quick]
//! ```

use std::fmt::Write as _;

use tcpa_energy::bench_util::{
    bench_sim_json_path, time_once, write_bench_section,
};
use tcpa_energy::schedule::find_schedule;
use tcpa_energy::sim::{simulate, ArchConfig};
use tcpa_energy::tiling::{tile_pra, ArrayMapping};
use tcpa_energy::workloads::{self, workload_inputs};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[i64] = if quick { &[64, 128] } else { &[64, 128, 256, 512] };

    let wl = workloads::by_name("gesummv").unwrap();
    let phase = &wl.phases[0];
    let mapping = ArrayMapping::new(vec![8, 8]);
    let tiled = tile_pra(phase, &mapping);
    let schedule = find_schedule(&tiled, 1).unwrap();
    println!("simulator throughput (GESUMMV, 8x8 array)\n");
    println!(
        "{:>6} {:>14} {:>12} {:>16}",
        "N", "stmt execs", "wall", "execs/s"
    );
    let mut rows = String::from("[");
    for (i, &n) in sizes.iter().enumerate() {
        let params = mapping.params_for(&[n, n]);
        let env = workload_inputs(&wl, &[params.clone()]);
        let mut arch = ArchConfig::with_array(vec![8, 8]);
        arch.regs.fd = 1 << 20;
        let (t, res) =
            time_once(|| simulate(phase, &arch, &schedule, &params, &env));
        let execs = res.counters.executions;
        let execs_per_sec = execs as f64 / t.as_secs_f64().max(1e-12);
        println!(
            "{:>6} {:>14} {:>12.3?} {:>16.3e}",
            n, execs, t, execs_per_sec
        );
        let _ = write!(
            rows,
            "{}{{\"n\": {n}, \"stmt_execs\": {execs}, \
             \"wall_s\": {:.6}, \"execs_per_sec\": {execs_per_sec:.1}}}",
            if i > 0 { ", " } else { "" },
            t.as_secs_f64(),
        );
    }
    rows.push(']');

    let body = format!(
        "{{\"workload\": \"gesummv\", \"array\": \"8x8\", \
         \"rows\": {rows}, \"quick\": {quick}}}"
    );
    let path = bench_sim_json_path();
    write_bench_section(&path, "simulator_throughput", &body)
        .expect("writing BENCH_sim.json");
    println!("section simulator_throughput → {}", path.display());
}
