"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (multiples of the block size, kept small because
interpret-mode Pallas executes on CPU numpy) and dtypes (f32 exact-ish,
bf16 loose).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pallas_kernels as k
from compile.kernels import ref

DIMS = st.sampled_from([8, 16, 24])
SMALL = st.sampled_from([8, 16])
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


def rng_array(shape, dtype, seed):
    r = np.random.default_rng(seed)
    # eighths in [-1, 1]: keeps bf16 accumulation comparable to f32 refs
    q = r.integers(-8, 9, size=shape).astype(np.float32) / 8.0
    return jnp.asarray(q, dtype=dtype)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=1e-5, rtol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, kk=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_gemm_matches_ref(m, n, kk, dtype, seed):
    A = rng_array((m, kk), dtype, seed)
    B = rng_array((kk, n), dtype, seed + 1)
    got = k.gemm(A, B)
    want = ref.gemm(A.astype(jnp.float32), B.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), **tol(dtype)
    )


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_gesummv_matches_ref(m, n, dtype, seed):
    A = rng_array((m, n), dtype, seed)
    B = rng_array((m, n), dtype, seed + 1)
    x = rng_array((n,), dtype, seed + 2)
    got = k.gesummv(A, B, x)
    want = ref.gesummv(*(t.astype(jnp.float32) for t in (A, B, x)))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), **tol(dtype)
    )


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=SMALL, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_matvec_matches_ref(m, n, dtype, seed):
    A = rng_array((m, n), dtype, seed)
    x = rng_array((n,), dtype, seed + 1)
    got = k.matvec(A, x)
    want = ref.matvec(A.astype(jnp.float32), x.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), **tol(dtype)
    )


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32]),
    steps=st.integers(2, 5),
    seed=st.integers(0, 2**31),
)
def test_jacobi_step_matches_ref(n, steps, seed):
    v = rng_array((n,), jnp.float32, seed)
    got = v
    for _ in range(steps - 1):
        got = k.jacobi1d_step(got)
    want = ref.jacobi1d(v, steps)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(m=SMALL, n=SMALL, kk=SMALL, seed=st.integers(0, 2**31))
def test_gemm_block_size_invariance(m, n, kk, seed):
    """The block decomposition must not change the numerics."""
    A = rng_array((m, kk), jnp.float32, seed)
    B = rng_array((kk, n), jnp.float32, seed + 1)
    full = k.gemm(A, B, bm=m, bn=n)  # one block = whole problem
    blocked = k.gemm(A, B, bm=8, bn=8)
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(blocked), atol=1e-5, rtol=1e-5
    )


def test_block_must_divide():
    A = jnp.zeros((12, 8), jnp.float32)
    B = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(AssertionError):
        k.gemm(A, B, bm=8, bn=8)
