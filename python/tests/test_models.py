"""L2 correctness: every MANIFEST model vs its oracle at the AOT shapes,
plus lowering smoke tests (the HLO text the Rust runtime will consume)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.aot import to_hlo_text
from compile.kernels import ref


def synth(shape, seed):
    r = np.random.default_rng(seed)
    return jnp.asarray(
        r.integers(-8, 9, size=shape).astype(np.float32) / 8.0
    )


def args_for(name):
    _, specs = M.MANIFEST[name]
    return [synth(s.shape, i + 7) for i, s in enumerate(specs)]


def test_manifest_complete():
    assert sorted(M.MANIFEST) == sorted(
        [
            "gesummv", "gemm", "atax", "bicg", "mvt", "syrk", "k2mm",
            "jacobi1d", "doitgen", "gemver",
        ]
    )


def test_gesummv_model():
    A, B, x = args_for("gesummv")
    (y,) = M.gesummv(A, B, x)
    np.testing.assert_allclose(y, ref.gesummv(A, B, x), atol=1e-5, rtol=1e-5)


def test_gemm_model():
    A, B = args_for("gemm")
    (c,) = M.gemm(A, B)
    np.testing.assert_allclose(c, ref.gemm(A, B), atol=1e-5, rtol=1e-5)


def test_atax_model():
    A, x = args_for("atax")
    y, tmp = M.atax(A, x)
    np.testing.assert_allclose(y, ref.atax(A, x), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(tmp, A @ x, atol=1e-5, rtol=1e-5)


def test_bicg_model():
    A, p, r = args_for("bicg")
    q, s = M.bicg(A, p, r)
    rq, rs = ref.bicg(A, p, r)
    np.testing.assert_allclose(q, rq, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(s, rs, atol=1e-5, rtol=1e-5)


def test_mvt_model():
    args = args_for("mvt")
    x1, x2 = M.mvt(*args)
    r1, r2 = ref.mvt(*args)
    np.testing.assert_allclose(x1, r1, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(x2, r2, atol=1e-5, rtol=1e-5)


def test_syrk_model():
    A, Cin = args_for("syrk")
    (c,) = M.syrk(A, Cin)
    np.testing.assert_allclose(c, ref.syrk(A, Cin), atol=1e-5, rtol=1e-5)


def test_k2mm_model():
    A, B, C = args_for("k2mm")
    d, tmp = M.k2mm(A, B, C)
    np.testing.assert_allclose(d, ref.k2mm(A, B, C), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(tmp, A @ B, atol=1e-5, rtol=1e-5)


def test_jacobi_model():
    (a,) = args_for("jacobi1d")
    (v,) = M.MANIFEST["jacobi1d"][0](a)
    np.testing.assert_allclose(
        v, ref.jacobi1d(a, 4), atol=1e-4, rtol=1e-4
    )


def test_doitgen_model():
    A, C4 = args_for("doitgen")
    (s,) = M.doitgen(A, C4)
    want = jnp.einsum("rqs,sp->rqp", A, C4)
    np.testing.assert_allclose(s, want, atol=1e-5, rtol=1e-5)


def test_gemver_model():
    A, u1, v1, u2, v2, y, z = args_for("gemver")
    B, x, w = M.gemver(A, u1, v1, u2, v2, y, z)
    B_ref = A + jnp.outer(u1, v1) + jnp.outer(u2, v2)
    x_ref = B_ref.T @ y + z
    w_ref = B_ref @ x_ref
    np.testing.assert_allclose(B, B_ref, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(x, x_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(w, w_ref, atol=1e-3, rtol=1e-3)


def test_all_models_lower_to_hlo_text():
    """Lowering smoke: every artifact the Makefile produces is non-empty
    HLO text with an ENTRY computation (what HloModuleProto::from_text_file
    parses on the Rust side)."""
    for name, (fn, specs) in M.MANIFEST.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        assert "ENTRY" in text, name
        assert "f32" in text, name
