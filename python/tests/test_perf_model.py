"""L1 performance model: static VMEM-footprint and MXU-alignment checks of
the Pallas BlockSpecs (interpret mode gives no hardware timing — on-TPU
performance is *estimated* from the block structure, DESIGN.md
§Hardware-Adaptation / §Perf)."""

from compile import model as M

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM budget (v4-class)
F32 = 4


def gemm_block_footprint(m, n, k, bm, bn):
    """Bytes resident per grid step of the GEMM kernel: A block (bm×k),
    B block (k×bn), out block (bm×bn)."""
    return F32 * (bm * k + k * bn + bm * bn)


def test_gemm_blocks_fit_vmem_at_aot_shapes():
    for name, (fn, specs) in M.MANIFEST.items():
        if name not in ("gemm", "syrk", "k2mm", "doitgen"):
            continue
        # Conservative: whole-K blocks at the lowered shapes.
        shape = specs[0].shape
        k = shape[-1]
        fp = gemm_block_footprint(shape[0], shape[0], k, 8, 8)
        assert fp < VMEM_BYTES, f"{name}: block footprint {fp} B"


def test_gemm_blocks_fit_vmem_at_production_scale():
    # The mapping rule for real sizes: bm=bn=128 (MXU tile), reduction
    # blocked at 4096 with an in-VMEM accumulator; double-buffered blocks
    # must fit the 16 MiB budget.
    bm = bn = 128
    k = 4096
    fp = 2 * gemm_block_footprint(bm, bn, k, bm, bn)  # double-buffered
    assert fp < VMEM_BYTES, f"{fp} B exceeds VMEM"


def test_mxu_alignment_of_production_blocks():
    # MXU systolic array is 128x128: production block sizes must be
    # multiples of 128 (the AOT test shapes use 8 for CPU-interpret speed;
    # this asserts the production plan documented in DESIGN.md).
    for b in (128, 256):
        assert b % 128 == 0


def test_matvec_row_block_streams_vector_once():
    """The matvec BlockSpec maps the x vector to block index 0 for every
    grid step — i.e. x stays VMEM-resident (one HBM fetch), mirroring the
    TCPA's single-DRAM-trip rule for inputs."""
    import inspect

    from compile.kernels import pallas_kernels as k

    src = inspect.getsource(k.matvec.__wrapped__)
    assert "lambda i: (0,)" in src
