"""AOT lowering: JAX models → HLO **text** artifacts for the Rust runtime.

HLO text (not ``serialize()``d protos) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the xla_extension
0.5.1 bundled with the published ``xla`` crate rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (wired into
``make artifacts``; a no-op for unchanged inputs thanks to the Makefile
stamp).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import MANIFEST


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, (fn, args) in MANIFEST.items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shapes = ";".join(
            ",".join(str(d) for d in a.shape) if a.shape else "scalar"
            for a in args
        )
        manifest_lines.append(f"{name} {shapes}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    lower_all(args.out_dir)


if __name__ == "__main__":
    main()
