"""Layer-2 JAX models: one function per workload, calling the Layer-1
Pallas kernels. Lowered once by ``aot.py`` to HLO text; never imported at
runtime by the Rust coordinator.

Every model's signature and the shapes it is lowered at are listed in
``MANIFEST`` — the single source of truth shared with ``aot.py`` and (via
``artifacts/manifest.txt``) with the Rust runtime.
"""

import jax.numpy as jnp

from .kernels import pallas_kernels as k


def gesummv(A, B, x):
    return (k.gesummv(A, B, x),)


def gemm(A, B):
    return (k.gemm(A, B),)


def atax(A, x):
    tmp = k.matvec(A, x)
    y = k.matvec(A.T, tmp)
    return (y, tmp)


def bicg(A, p, r):
    return (k.matvec(A, p), k.matvec(A.T, r))


def mvt(A, y1, y2, x1, x2):
    return (x1 + k.matvec(A, y1), x2 + k.matvec(A.T, y2))


def syrk(A, Cin):
    return (k.gemm(A, A.T) + Cin,)


def k2mm(A, B, C):
    tmp = k.gemm(A, B)
    return (k.gemm(tmp, C), tmp)


def doitgen(A, C4):
    """SUM[r,q,p] = Σ_s A[r,q,s]·C4[s,p] via the blocked GEMM kernel on the
    flattened (r,q) axis."""
    nr, nq, ns = A.shape
    flat = A.reshape(nr * nq, ns)
    return (k.gemm(flat, C4).reshape(nr, nq, C4.shape[1]),)


def gemver(A, u1, v1, u2, v2, y, z):
    B = A + jnp.outer(u1, v1) + jnp.outer(u2, v2)
    x = k.matvec(B.T, y) + z
    w = k.matvec(B, x)
    return (B, x, w)


def jacobi1d_steps(steps):
    """Build a fixed-sweep-count Jacobi model (steps is static: the AOT
    artifact bakes the time extent, like the unrolled TCPA schedule)."""

    def model(a):
        v = a
        for _ in range(steps - 1):
            v = k.jacobi1d_step(v)
        return (v,)

    return model


def _f32(*shape):
    import jax

    return jax.ShapeDtypeStruct(shape, jnp.float32)


#: name -> (callable, example argument shapes)
#: Shapes are the ones the AOT artifacts are compiled for; the Rust
#: end-to-end driver uses exactly these.
MANIFEST = {
    "gesummv": (gesummv, [_f32(16, 16), _f32(16, 16), _f32(16)]),
    "gemm": (gemm, [_f32(16, 16), _f32(16, 16)]),
    "atax": (atax, [_f32(16, 16), _f32(16)]),
    "bicg": (bicg, [_f32(16, 16), _f32(16), _f32(16)]),
    "mvt": (
        mvt,
        [_f32(16, 16), _f32(16), _f32(16), _f32(16), _f32(16)],
    ),
    "syrk": (syrk, [_f32(16, 16), _f32(16, 16)]),
    "k2mm": (k2mm, [_f32(16, 16), _f32(16, 16), _f32(16, 16)]),
    "jacobi1d": (jacobi1d_steps(4), [_f32(32)]),
    "doitgen": (doitgen, [_f32(4, 4, 8), _f32(8, 8)]),
    "gemver": (
        gemver,
        [
            _f32(16, 16), _f32(16), _f32(16), _f32(16), _f32(16),
            _f32(16), _f32(16),
        ],
    ),
}
