"""Pure-jnp reference oracles for every workload.

These are the L1 correctness ground truth: the Pallas kernels (and through
them the AOT artifacts the Rust runtime executes) are asserted allclose
against these functions by pytest/hypothesis at build time. They are also
the *numeric twins* of the Rust PRA definitions in
``rust/src/workloads/`` — same simplifications (GEMM without alpha/beta,
unscaled Jacobi, rectangular SYRK), documented in DESIGN.md §6.
"""

import jax.numpy as jnp


def gesummv(A, B, x):
    """Y = (A + B)·x — the paper's running example."""
    return (A + B) @ x


def gemm(A, B):
    """C = A·B."""
    return A @ B


def matvec(A, x):
    """y = A·x (building block for ATAX/BiCG/MVT)."""
    return A @ x


def atax(A, x):
    """y = Aᵀ(A·x)."""
    return A.T @ (A @ x)


def bicg(A, p, r):
    """(q, s) = (A·p, Aᵀ·r)."""
    return A @ p, A.T @ r


def mvt(A, y1, y2, x1, x2):
    """(x1 + A·y1, x2 + Aᵀ·y2)."""
    return x1 + A @ y1, x2 + A.T @ y2


def syrk(A, Cin):
    """C = A·Aᵀ + Cin (rectangular update)."""
    return A @ A.T + Cin


def k2mm(A, B, C):
    """D = (A·B)·C."""
    return (A @ B) @ C


def jacobi1d(a, steps):
    """``steps − 1`` unscaled relaxation sweeps v[i] = v[i−1]+v[i]+v[i+1]
    (boundaries propagate unchanged), matching the PRA where sweep t = 0 is
    the load of the initial array."""
    v = a
    for _ in range(int(steps) - 1):
        v = jnp.concatenate([v[:1], v[:-2] + v[1:-1] + v[2:], v[-1:]])
    return v
