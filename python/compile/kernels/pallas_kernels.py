"""Layer-1 Pallas kernels mirroring the TCPA LSGP mapping.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): one TCPA *tile* of
size ``p0×p1`` maps to one Pallas *block* resident in VMEM; the grid walks
the tile origins exactly like the array's tile grid `K`. Reduction
dimensions that the TCPA mapping keeps PE-local (``t_ℓ = 1``) stay whole
inside the block — the accumulation chain that lives in FD registers on
the TCPA becomes a VMEM-resident accumulator here.

All kernels run with ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO so the AOT artifacts run anywhere (see /opt/xla-example/README).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# --------------------------------------------------------------------------
# GEMM: grid over (M/bm, N/bn) tile origins; K stays in-block (t_K = 1).
# --------------------------------------------------------------------------
def _gemm_kernel(a_ref, b_ref, o_ref):
    # One (bm, K)×(K, bn) product: the per-PE accumulation chain.
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gemm(A, B, *, bm=8, bn=8):
    """C = A·B with a (bm × bn) block ↔ TCPA tile mapping."""
    m, k = A.shape
    k2, n = B.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert m % bm == 0 and n % bn == 0, "block must divide shape"
    return pl.pallas_call(
        _gemm_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), A.dtype),
        interpret=True,
    )(A, B)


# --------------------------------------------------------------------------
# GESUMMV: grid over row blocks; the i1 accumulation chain stays in-block.
# --------------------------------------------------------------------------
def _gesummv_kernel(a_ref, b_ref, x_ref, o_ref):
    s = a_ref[...] + b_ref[...]
    o_ref[...] = s @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("bm",))
def gesummv(A, B, x, *, bm=8):
    """Y = (A + B)·x, row-blocked like the paper's GESUMMV tiling."""
    m, n = A.shape
    assert m % bm == 0, "block must divide rows"
    return pl.pallas_call(
        _gesummv_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), A.dtype),
        interpret=True,
    )(A, B, x)


# --------------------------------------------------------------------------
# MATVEC: row-blocked y = A·x (building block for ATAX/BiCG/MVT models).
# --------------------------------------------------------------------------
def _matvec_kernel(a_ref, x_ref, o_ref):
    o_ref[...] = a_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("bm",))
def matvec(A, x, *, bm=8):
    """y = A·x with row blocks."""
    m, n = A.shape
    assert m % bm == 0, "block must divide rows"
    return pl.pallas_call(
        _matvec_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), A.dtype),
        interpret=True,
    )(A, x)


# --------------------------------------------------------------------------
# Jacobi-1D: one relaxation sweep per call; whole line in one block (the
# TCPA maps the stencil line across PEs, but a sweep is the natural
# kernel granularity for the VMEM scratchpad).
# --------------------------------------------------------------------------
def _jacobi_kernel(v_ref, o_ref):
    v = v_ref[...]
    inner = v[:-2] + v[1:-1] + v[2:]
    o_ref[...] = jnp.concatenate([v[:1], inner, v[-1:]])


@jax.jit
def jacobi1d_step(v):
    """One unscaled Jacobi sweep with propagated boundaries."""
    (n,) = v.shape
    return pl.pallas_call(
        _jacobi_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), v.dtype),
        interpret=True,
    )(v)
